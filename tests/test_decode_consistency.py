"""Decode path must reproduce full-sequence forward logits step by step —
validates cache bookkeeping, rotary offsets, ring buffers, SSM recurrence
and MLA absorbed-matmul decode across every attention/mixer family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.models import (HybridConfig, MLAConfig, MoEConfig, ModelConfig,
                          SSMConfig, decode_step, forward, init_cache,
                          init_params)

B, S = 2, 16

CASES = [
    ModelConfig(name="gqa", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97),
    ModelConfig(name="sw", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                sliding_window=8),
    ModelConfig(name="mla", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=97,
                attn_type="mla",
                mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)),
    ModelConfig(name="moe", arch_type="moe", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                              capacity_factor=8.0)),
    ModelConfig(name="mamba1", arch_type="ssm", num_layers=2, d_model=64,
                num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=97,
                attn_type="none", rope_style="none",
                ssm=SSMConfig(version=1, state_size=4)),
    ModelConfig(name="mamba2", arch_type="ssm", num_layers=2, d_model=64,
                num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=97,
                attn_type="none", rope_style="none",
                ssm=SSMConfig(version=2, state_size=8, head_dim=16)),
    ModelConfig(name="hybrid", arch_type="hybrid", num_layers=4, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=97,
                ssm=SSMConfig(version=2, state_size=8, head_dim=16),
                hybrid=HybridConfig(attn_every=2)),
]


@pytest.mark.parametrize("cfg", CASES, ids=lambda c: c.name)
def test_decode_matches_forward(cfg):
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    logits_full, *_ = forward(params, cfg, {"tokens": tokens})
    if cfg.sliding_window:
        # full forward masks by window; decode must agree within the window
        pass
    cache = init_cache(cfg, B, S if not cfg.sliding_window
                       else cfg.sliding_window)
    dec = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
    outs = []
    for t in range(S):
        lg, cache = dec(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(logits_full - logits_dec)))
    scale = float(jnp.max(jnp.abs(logits_full))) + 1.0
    assert err < 2e-3 * scale, f"{cfg.name}: decode mismatch {err}"


# ---- paged serving cache vs the contiguous generate path ----


@pytest.mark.parametrize("flash", [False, True], ids=["xla", "flash"])
def test_paged_decode_matches_llm_generate(flash):
    """The paged KV cache (page pool + page tables, the serving engine's
    layout) is token-exact against the contiguous ``llm_generate``: same
    greedy tokens, same first-token logits, same <SEG> embedding. Pages
    are laid out non-contiguously and a second batch row shares the
    prefix pages read-only — the multi-UAV serving configuration."""
    import numpy as np

    from repro.configs.lisa_mini import CONFIG as PCFG
    from repro.core import vlm
    from repro.core.paging import pages_for, prefix_positions

    pcfg = dataclasses.replace(
        PCFG, llm=PCFG.llm.replace(use_flash_decode=flash))
    params = vlm.init_lisa(pcfg, jax.random.PRNGKey(0))
    qlen, T, page = 8, 4, 16
    ctx = jax.random.normal(jax.random.PRNGKey(1),
                            (1, pcfg.clip_tokens, pcfg.llm.d_model))
    query = jax.random.randint(jax.random.PRNGKey(2), (1, qlen), 0,
                               pcfg.llm.vocab_size)
    tokens_ref, logits0_ref, seg_ref = vlm.llm_generate(params, pcfg, ctx,
                                                        query, T)

    S = pcfg.clip_tokens + qlen
    n_prefix, n_private = pages_for(S, page), pages_for(T, page)
    logits0, _, paged = vlm.llm_prefill_paged(params, pcfg, ctx, query, page)
    np.testing.assert_allclose(np.asarray(logits0), np.asarray(logits0_ref),
                               atol=1e-5)

    # pool: trash page 0, then scattered prefix/private pages; two rows
    # share the prefix read-only, each with its own private decode pages
    B = 2
    P = 1 + n_prefix + B * n_private
    prefix_ids = np.arange(1, 1 + n_prefix)
    pool = {"groups": [jax.tree.map(
        lambda a: jnp.zeros((a.shape[0], P) + a.shape[3:], a.dtype)
        .at[:, prefix_ids].set(a[:, 0]), paged["groups"][0])]}
    pt = np.zeros((B, n_prefix + n_private), np.int32)
    positions = np.full((B, (n_prefix + n_private) * page), -1, np.int32)
    for b in range(B):
        priv = 1 + n_prefix + b * n_private
        pt[b] = list(prefix_ids) + list(range(priv, priv + n_private))
        positions[b, :n_prefix * page] = prefix_positions(S, n_prefix, page)

    toks = [int(jnp.argmax(logits0[0]))]
    base = n_prefix * page
    seg = None
    for t in range(T):
        tk = np.full((B, 1), toks[-1], np.int32)
        pos = np.full((B,), S + t, np.int32)
        ws = np.full((B,), base + t, np.int32)
        logits, seg, pool = vlm.llm_decode_step_paged(
            params, pcfg, pool, pt, positions, tk, pos, ws)
        positions[:, base + t] = S + t
        if t < T - 1:
            toks.append(int(jnp.argmax(logits[0])))
    assert np.array_equal(np.asarray(tokens_ref)[0], np.asarray(toks))
    # both rows decoded the same sequence; row 1 through shared prefix
    # pages — identical hidden states prove the pages were untouched
    seg = np.asarray(seg)
    scale = float(jnp.max(jnp.abs(seg_ref))) + 1.0
    assert float(np.max(np.abs(seg[0] - np.asarray(seg_ref)[0]))) \
        < 2e-3 * scale
    np.testing.assert_allclose(seg[0], seg[1], atol=1e-6)


# ---- speculative decoding: multi-token verify + draft/accept/rollback ----


@pytest.mark.parametrize("flash", [False, True], ids=["xla", "flash"])
def test_verify_step_matches_sequential_decode_steps(flash):
    """One multi-token verify pass (``llm_verify_step_paged``) over a
    C-token chunk reproduces C successive single-token paged decode
    steps position by position — including a row whose chunk is shorter
    than C (pad entries write to the trash page and change nothing)."""
    import numpy as np

    from repro.configs.lisa_mini import CONFIG as PCFG
    from repro.core import vlm
    from repro.core.paging import pages_for, prefix_positions

    pcfg = dataclasses.replace(
        PCFG, llm=PCFG.llm.replace(use_flash_decode=flash))
    params = vlm.init_lisa(pcfg, jax.random.PRNGKey(0))
    qlen, T, page = 8, 4, 16
    ctx = jax.random.normal(jax.random.PRNGKey(1),
                            (1, pcfg.clip_tokens, pcfg.llm.d_model))
    query = jax.random.randint(jax.random.PRNGKey(2), (1, qlen), 0,
                               pcfg.llm.vocab_size)
    S = pcfg.clip_tokens + qlen
    n_prefix, n_private = pages_for(S, page), pages_for(T, page)
    logits0, _, paged = vlm.llm_prefill_paged(params, pcfg, ctx, query, page)

    B = 2
    P = 1 + n_prefix + B * n_private
    prefix_ids = np.arange(1, 1 + n_prefix)
    def fresh_pool():
        return {"groups": [jax.tree.map(
            lambda a: jnp.zeros((a.shape[0], P) + a.shape[3:], a.dtype)
            .at[:, prefix_ids].set(a[:, 0]), paged["groups"][0])]}
    pt = np.zeros((B, n_prefix + n_private), np.int32)
    positions = np.full((B, (n_prefix + n_private) * page), -1, np.int32)
    for b in range(B):
        priv = 1 + n_prefix + b * n_private
        pt[b] = list(prefix_ids) + list(range(priv, priv + n_private))
        positions[b, :n_prefix * page] = prefix_positions(S, n_prefix, page)
    base = n_prefix * page

    # oracle: T sequential single-token paged decode steps
    pool = fresh_pool()
    pos_seq = positions.copy()
    toks = [int(jnp.argmax(logits0[0]))]
    seq_logits, seq_seg = [], []
    for t in range(T):
        tk = np.full((B, 1), toks[-1], np.int32)
        lg, sg, pool = vlm.llm_decode_step_paged(
            params, pcfg, pool, pt, pos_seq, tk,
            np.full((B,), S + t, np.int32), np.full((B,), base + t,
                                                    np.int32))
        pos_seq[:, base + t] = S + t
        seq_logits.append(np.asarray(lg))
        seq_seg.append(np.asarray(sg))
        toks.append(int(jnp.argmax(lg[0])))

    # one verify chunk: row 0 carries all T tokens, row 1 only 2 (padded)
    chunk = np.tile(np.asarray(toks[:T], np.int32), (B, 1))
    clens = np.asarray([T, 2], np.int32)
    lgv, segv, _ = vlm.llm_verify_step_paged(
        params, pcfg, fresh_pool(), pt, positions, chunk,
        np.full((B,), S, np.int32), np.full((B,), base, np.int32), clens)
    lgv, segv = np.asarray(lgv), np.asarray(segv)
    scale = max(float(np.max(np.abs(l))) for l in seq_logits) + 1.0
    for b in range(B):
        for i in range(int(clens[b])):
            assert float(np.max(np.abs(lgv[b, i] - seq_logits[i][b]))) \
                < 2e-3 * scale, (b, i)
            sscale = float(np.max(np.abs(seq_seg[i][b]))) + 1.0
            assert float(np.max(np.abs(segv[b, i] - seq_seg[i][b]))) \
                < 2e-3 * sscale, (b, i)


@pytest.fixture(scope="module")
def spec_executor():
    """Small serving executor for the speculative-decode pins (tiny
    pages so draft overhangs cross page boundaries and rollback really
    fires)."""
    import numpy as np

    from repro.configs.lisa_mini import CONFIG as PCFG
    from repro.core import DualStreamExecutor, paper_lut, profile as prof
    lut = paper_lut()
    params, bns, _ = prof.random_init_system(PCFG, lut=lut)
    return DualStreamExecutor(pcfg=PCFG, params=params, bottlenecks=bns,
                              lut=lut, max_new_tokens=6,
                              flash_decode=False, page_size=4)


def _spec_requests(executor, n, seed):
    import numpy as np

    from repro.core.intent import Intent
    from repro.data import floodseg
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n):
        kind = "any" if i % 3 == 2 else "segment"
        b = floodseg.make_batch(rng, 1, kind, augment=False)
        img = jnp.asarray(b["images"])
        if kind == "any":
            pkt, _ = executor.edge_context(img, i, 0.0)
            out.append((pkt, b["query"], Intent.CONTEXT))
        else:
            pkt = executor.edge_insight(img, executor.lut.tiers[i % 2], i,
                                        0.0)
            out.append((pkt, b["query"], Intent.INSIGHT))
    return out


def _assert_matches_generate(executor, done, reqs):
    import numpy as np

    from repro.core.intent import Intent
    for i, (pkt, q, it) in enumerate(reqs):
        out = executor.cloud_generate_batch([pkt], [q])[0]
        assert np.array_equal(done[i]["tokens"], out[-1]), i
        if it is Intent.INSIGHT:
            np.testing.assert_allclose(done[i]["mask_logits"], out[0],
                                       atol=3e-4)
        np.testing.assert_allclose(done[i]["answer_logits"], out[-2]
                                   if it is Intent.CONTEXT else out[1],
                                   atol=3e-4)


@pytest.mark.parametrize("shared_draft", [True, False],
                         ids=["context_draft", "divergent_draft"])
def test_speculative_decode_token_exact_with_llm_generate(spec_executor,
                                                          shared_draft):
    """Greedy speculative decode through the in-flight batch is token-
    exact with the one-shot ``llm_generate`` path — with the warm
    Context-stream weights drafting (near-total acceptance) and with a
    divergent random draft (rejections force corrections + page
    rollback), under slot reuse (more requests than slots)."""
    import numpy as np

    from repro.engine.inflight import InflightDecoder
    from repro.engine.speculative import SpeculativeConfig

    if shared_draft:
        spec = SpeculativeConfig(draft_tokens=3)
    else:
        from repro.configs.lisa_mini import CONFIG as PCFG
        from repro.core import vlm
        spec = SpeculativeConfig(
            draft_tokens=4,
            draft_params=vlm.init_lisa(PCFG, jax.random.PRNGKey(99)))
    reqs = _spec_requests(spec_executor, 5, seed=13 if shared_draft else 17)
    dec = InflightDecoder(spec_executor, slots=2, spec=spec)
    done = {}
    for i, (pkt, q, it) in enumerate(reqs):
        dec.submit(i, it, pkt, q,
                   lambda out: done.setdefault(out["seq_id"], out))
    dec.drain()
    assert len(done) == len(reqs)
    _assert_matches_generate(spec_executor, done, reqs)
    st = dec.spec_stats
    assert st.row_steps > 0 and st.drafted > 0
    if shared_draft:
        # the Context model *is* the serving model here: full acceptance
        assert st.acceptance_rate == 1.0
        assert st.tokens_per_step >= 1.5
    else:
        # a divergent draft gets rejected and must roll pages back —
        # output is exact anyway (acceptance only moves the cost)
        assert st.acceptance_rate < 1.0
        assert st.pages_rolled_back > 0
    # every private/draft page returned; only cached prefixes pinned
    from repro.core.paging import pages_for
    qlen = np.asarray(reqs[0][1]).shape[-1]
    per_prefix = pages_for(spec_executor.pcfg.clip_tokens + qlen,
                           spec_executor.page_size)
    assert dec.pool.pages_in_use == len(dec.pool.prefix) * per_prefix


def test_mixed_speculative_and_plain_rows_one_batch(spec_executor):
    """Speculating and plain rows share one in-flight verify batch (the
    plain row rides a chunk of one) — both remain token-exact with the
    one-shot generate path."""
    import numpy as np

    from repro.engine.inflight import InflightDecoder
    from repro.engine.speculative import SpeculativeConfig

    reqs = _spec_requests(spec_executor, 4, seed=23)
    dec = InflightDecoder(spec_executor, slots=4,
                          spec=SpeculativeConfig(draft_tokens=3))
    done = {}
    for i, (pkt, q, it) in enumerate(reqs):
        dec.submit(i, it, pkt, q,
                   lambda out: done.setdefault(out["seq_id"], out),
                   speculative=(i % 2 == 0))   # every other row plain
    dec.drain()
    _assert_matches_generate(spec_executor, done, reqs)
    assert [done[i]["speculative"] for i in range(4)] \
        == [True, False, True, False]
    # speculating rows finished in fewer steps than the plain rows'
    # T+1-step lockstep, so the batch really mixed disciplines
    assert dec.spec_stats.row_steps > 0
    assert dec.spec_stats.tokens_per_step > 1.0


def test_draft_reuses_prefix_prefill_on_repeat_frames(spec_executor):
    """Repeat-prefix frames skip the draft model's prefill too (keyed
    like the target prefix store) — and still serve exact results."""
    import numpy as np

    from repro.core.intent import Intent
    from repro.data import floodseg
    from repro.engine.inflight import InflightDecoder
    from repro.engine.speculative import SpeculativeConfig

    rng = np.random.RandomState(29)
    b = floodseg.make_batch(rng, 1, "segment", augment=False)
    img = jnp.asarray(b["images"])
    dec = InflightDecoder(spec_executor, slots=2,
                          spec=SpeculativeConfig(draft_tokens=3))
    done = {}
    for i in range(3):         # same frame + standing query: same prefix
        pkt = spec_executor.edge_insight(img, spec_executor.lut.tiers[0],
                                         i, 0.0)
        dec.submit(i, Intent.INSIGHT, pkt, b["query"],
                   lambda out: done.setdefault(out["seq_id"], out),
                   operator_id="uav-A")
    dec.drain()
    assert dec.draft.n_prefills == 1          # one draft prefill, 3 frames
    out = spec_executor.cloud_generate_batch([pkt], [b["query"]])[0]
    for i in range(3):
        assert np.array_equal(done[i]["tokens"], out[-1])
    # shared rows survive decoder retirement (the engine passes one dict
    # per engine): a successor decoder skips the prefill entirely
    dec2 = InflightDecoder(spec_executor, slots=2, pool=dec.pool,
                           spec=SpeculativeConfig(draft_tokens=3),
                           spec_prefix_rows=dec.draft._prefix_rows)
    pkt2 = spec_executor.edge_insight(img, spec_executor.lut.tiers[0], 9,
                                      0.0)
    done2 = {}
    dec2.submit(9, Intent.INSIGHT, pkt2, b["query"],
                lambda out: done2.setdefault(out["seq_id"], out),
                operator_id="uav-A")
    dec2.drain()
    assert dec2.draft.n_prefills == 0
    assert np.array_equal(done2[9]["tokens"], out[-1])
