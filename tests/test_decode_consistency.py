"""Decode path must reproduce full-sequence forward logits step by step —
validates cache bookkeeping, rotary offsets, ring buffers, SSM recurrence
and MLA absorbed-matmul decode across every attention/mixer family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.models import (HybridConfig, MLAConfig, MoEConfig, ModelConfig,
                          SSMConfig, decode_step, forward, init_cache,
                          init_params)

B, S = 2, 16

CASES = [
    ModelConfig(name="gqa", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97),
    ModelConfig(name="sw", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                sliding_window=8),
    ModelConfig(name="mla", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=97,
                attn_type="mla",
                mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)),
    ModelConfig(name="moe", arch_type="moe", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                              capacity_factor=8.0)),
    ModelConfig(name="mamba1", arch_type="ssm", num_layers=2, d_model=64,
                num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=97,
                attn_type="none", rope_style="none",
                ssm=SSMConfig(version=1, state_size=4)),
    ModelConfig(name="mamba2", arch_type="ssm", num_layers=2, d_model=64,
                num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=97,
                attn_type="none", rope_style="none",
                ssm=SSMConfig(version=2, state_size=8, head_dim=16)),
    ModelConfig(name="hybrid", arch_type="hybrid", num_layers=4, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=97,
                ssm=SSMConfig(version=2, state_size=8, head_dim=16),
                hybrid=HybridConfig(attn_every=2)),
]


@pytest.mark.parametrize("cfg", CASES, ids=lambda c: c.name)
def test_decode_matches_forward(cfg):
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    logits_full, *_ = forward(params, cfg, {"tokens": tokens})
    if cfg.sliding_window:
        # full forward masks by window; decode must agree within the window
        pass
    cache = init_cache(cfg, B, S if not cfg.sliding_window
                       else cfg.sliding_window)
    dec = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
    outs = []
    for t in range(S):
        lg, cache = dec(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(logits_full - logits_dec)))
    scale = float(jnp.max(jnp.abs(logits_full))) + 1.0
    assert err < 2e-3 * scale, f"{cfg.name}: decode mismatch {err}"
