"""Scheduler unit contracts: QoS class mapping, weighted-fair stride
arbitration, strict priority bands, idle catch-up, token-bucket rate
limits, bounded-queue shed, preemption picking rules, FIFO equivalence,
and the shared prototype/spawn telemetry surface."""
import dataclasses
from types import SimpleNamespace
from typing import Optional

import pytest

from repro.core.intent import Intent
from repro.engine import (QOS_LATENCY, QOS_THROUGHPUT, FifoScheduler,
                          QoSScheduler, jain_index, qos_class)


@dataclasses.dataclass
class Item:
    """The slice of ``_PendingRequest`` the scheduler contracts use."""
    seq_id: int
    intent: Intent
    priority: int = 0
    deadline: Optional[float] = None
    t_enqueue: float = 0.0
    queue_wait: float = 0.0
    resumes: int = 0


def _active(slot_specs):
    """{slot: state} the way ``pick_preemption`` sees it: a request with
    intent/priority/resumes plus the tokens generated so far."""
    return {s: SimpleNamespace(
        req=SimpleNamespace(intent=intent, priority=prio, resumes=resumes),
        tokens=list(range(n_tokens)))
        for s, (intent, prio, n_tokens, resumes) in slot_specs.items()}


def _pop_all(sched, n, now=0.0):
    out = []
    for _ in range(n):
        it = sched.pop_next(now)
        if it is None:
            break
        out.append(it)
    return out


# ---- class mapping + fairness index ----


def test_qos_class_mapping():
    assert qos_class(Intent.CONTEXT) == QOS_LATENCY
    assert qos_class(Intent.INSIGHT) == QOS_THROUGHPUT


def test_jain_index_bounds():
    assert jain_index([5, 5, 5, 5]) == pytest.approx(1.0)
    assert jain_index([20, 0, 0, 0]) == pytest.approx(0.25)
    assert jain_index([]) == 1.0


# ---- FIFO scheduler: the default, behavior-preserving policy ----


def test_fifo_is_arrival_order_and_never_rejects():
    s = FifoScheduler()
    items = [Item(i, Intent.INSIGHT if i % 2 else Intent.CONTEXT)
             for i in range(6)]
    assert all(s.enqueue(it, 0.0) is None for it in items)
    assert [it.seq_id for it in _pop_all(s, 6)] == list(range(6))
    assert s.admission_check("anyone", 0.0) is None
    assert s.pick_preemption(_active({0: (Intent.INSIGHT, 0, 1, 0)}),
                             1e9) is None


def test_fifo_requeue_preempted_goes_to_front():
    s = FifoScheduler()
    s.enqueue(Item(1, Intent.INSIGHT), 0.0)
    s.requeue_preempted(Item(9, Intent.INSIGHT), 0.0)
    assert s.pop_next(0.0).seq_id == 9


# ---- weighted-fair stride arbitration ----


def test_stride_gives_weighted_share():
    """Defaults (latency 2.0, throughput 1.0): over any backlogged
    stretch the latency class gets 2/3 of the pops."""
    s = QoSScheduler()
    for i in range(30):
        s.enqueue(Item(i, Intent.CONTEXT), 0.0)
        s.enqueue(Item(100 + i, Intent.INSIGHT), 0.0)
    popped = _pop_all(s, 30)
    n_lat = sum(1 for it in popped if it.intent is Intent.CONTEXT)
    assert n_lat == 20
    # and the throughput class is never starved outright
    assert any(it.intent is Intent.INSIGHT for it in popped[:3])


def test_custom_weights_flip_the_share():
    s = QoSScheduler(weights={QOS_LATENCY: 1.0, QOS_THROUGHPUT: 3.0})
    for i in range(40):
        s.enqueue(Item(i, Intent.CONTEXT), 0.0)
        s.enqueue(Item(100 + i, Intent.INSIGHT), 0.0)
    popped = _pop_all(s, 40)
    n_thr = sum(1 for it in popped if it.intent is Intent.INSIGHT)
    assert n_thr == 30


def test_nonpositive_weight_rejected():
    with pytest.raises(ValueError):
        QoSScheduler(weights={QOS_LATENCY: 0.0, QOS_THROUGHPUT: 1.0})


def test_idle_class_cannot_bank_credit():
    """A class returning from idle is caught up to the backlog floor:
    it must not repay its idle time with a monopolizing burst."""
    s = QoSScheduler()
    for i in range(20):
        s.enqueue(Item(i, Intent.CONTEXT), 0.0)
    _pop_all(s, 8)                      # throughput idle the whole time
    for i in range(12):
        s.enqueue(Item(100 + i, Intent.INSIGHT), 0.0)
    nxt = _pop_all(s, 9)
    n_thr = sum(1 for it in nxt if it.intent is Intent.INSIGHT)
    assert n_thr == 3                   # its fair 1/3, not a catch-up burst


# ---- strict priority bands ----


def test_priority_band_pops_first_across_classes():
    s = QoSScheduler()
    s.enqueue(Item(1, Intent.CONTEXT, priority=0), 0.0)
    s.enqueue(Item(2, Intent.INSIGHT, priority=2), 0.0)
    s.enqueue(Item(3, Intent.INSIGHT, priority=0), 0.0)
    assert s.pop_next(0.0).seq_id == 2  # the band outranks the class
    assert s.pop_next(0.0).seq_id == 1


def test_priority_within_class_skips_queue():
    s = QoSScheduler()
    s.enqueue(Item(1, Intent.INSIGHT, priority=0), 0.0)
    s.enqueue(Item(2, Intent.INSIGHT, priority=1), 0.0)
    assert s.pop_next(0.0).seq_id == 2


# ---- token-bucket rate limits + bounded queue ----


def test_token_bucket_sheds_and_refills():
    s = QoSScheduler(rate_per_s=1.0, burst=2.0)
    assert s.admission_check("op", 0.0) is None
    assert s.admission_check("op", 0.0) is None
    assert s.admission_check("op", 0.0) == "rate_limit"
    assert s.telemetry.rejected_rate_limit == 1
    assert s.admission_check("op", 1.0) is None   # refilled 1 token
    assert s.admission_check("op", 1.0) == "rate_limit"


def test_rate_override_targets_one_operator():
    s = QoSScheduler(rate_overrides={"spam": (1.0, 1.0)})
    for _ in range(5):
        assert s.admission_check("polite", 0.0) is None
    assert s.admission_check("spam", 0.0) is None
    assert s.admission_check("spam", 0.0) == "rate_limit"


def test_bounded_queue_sheds_per_class():
    s = QoSScheduler(max_queue=2)
    assert s.enqueue(Item(1, Intent.INSIGHT), 0.0) is None
    assert s.enqueue(Item(2, Intent.INSIGHT), 0.0) is None
    assert s.enqueue(Item(3, Intent.INSIGHT), 0.0) == "queue_full"
    # the other class has its own bound
    assert s.enqueue(Item(4, Intent.CONTEXT), 0.0) is None
    assert s.telemetry.rejected_queue_full == 1


# ---- preemption picking ----


def test_urgent_latency_item_preempts_lowest_ranked_victim():
    s = QoSScheduler(latency_patience_s=0.5)
    s.enqueue(Item(7, Intent.CONTEXT, t_enqueue=0.0), 0.0)
    active = _active({0: (Intent.INSIGHT, 0, 4, 0),
                      1: (Intent.INSIGHT, 0, 1, 0),
                      2: (Intent.CONTEXT, 0, 0, 0)})
    pick = s.pick_preemption(active, now=1.0)
    assert pick is not None
    item, victim = pick
    assert item.seq_id == 7
    assert victim == 1                  # lowest rank, fewest tokens lost
    assert len(s) == 0                  # the pick popped it


def test_patient_item_does_not_preempt():
    s = QoSScheduler(latency_patience_s=0.5)
    s.enqueue(Item(7, Intent.CONTEXT, t_enqueue=0.9), 0.0)
    active = _active({0: (Intent.INSIGHT, 0, 2, 0)})
    assert s.pick_preemption(active, now=1.0) is None
    assert len(s) == 1


def test_deadline_at_risk_is_urgent_even_for_throughput():
    s = QoSScheduler(preempt_slack_s=0.25, latency_patience_s=99.0)
    s.enqueue(Item(7, Intent.INSIGHT, priority=1, deadline=1.1,
                   t_enqueue=1.0), 1.0)
    active = _active({0: (Intent.INSIGHT, 0, 2, 0)})
    assert s.pick_preemption(active, now=1.0) is not None


def test_victim_must_rank_strictly_below():
    s = QoSScheduler(latency_patience_s=0.0)
    s.enqueue(Item(7, Intent.CONTEXT, t_enqueue=0.0), 0.0)
    # same rank (latency, prio 0) and higher rank (prio 1): no victim
    active = _active({0: (Intent.CONTEXT, 0, 2, 0),
                      1: (Intent.INSIGHT, 1, 2, 0)})
    assert s.pick_preemption(active, now=10.0) is None


def test_max_resumes_protects_thrashed_victim():
    s = QoSScheduler(latency_patience_s=0.0, max_resumes=2)
    s.enqueue(Item(7, Intent.CONTEXT, t_enqueue=0.0), 0.0)
    active = _active({0: (Intent.INSIGHT, 0, 2, 2)})  # parked twice already
    assert s.pick_preemption(active, now=10.0) is None


def test_preempt_false_disables_picking():
    s = QoSScheduler(preempt=False, latency_patience_s=0.0)
    s.enqueue(Item(7, Intent.CONTEXT, t_enqueue=0.0), 0.0)
    active = _active({0: (Intent.INSIGHT, 0, 2, 0)})
    assert s.pick_preemption(active, now=10.0) is None


def test_requeue_preempted_resumes_before_class_peers():
    s = QoSScheduler()
    s.enqueue(Item(1, Intent.INSIGHT), 0.0)
    s.requeue_preempted(Item(9, Intent.INSIGHT, resumes=1), 0.0)
    assert s.pop_next(0.0).seq_id == 9


# ---- prototype/spawn split, telemetry, load surface ----


def test_spawned_children_share_telemetry_and_buckets():
    proto = QoSScheduler(rate_per_s=1.0, burst=1.0)
    child = proto.spawn()
    assert child.telemetry is proto.telemetry
    # one fleet-wide bucket: the child's take drains the proto's view
    assert proto.admission_check("op", 0.0) is None
    assert child.admission_check("op", 0.0) == "rate_limit"
    child.enqueue(Item(1, Intent.CONTEXT, t_enqueue=0.0), 0.0)
    # prototype-level depth aggregates over children
    assert proto.load()["queue_depth_latency"] == 1
    it = child.pop_next(0.5)
    child.note_admitted(it, 0.5)
    assert it.queue_wait == pytest.approx(0.5)
    assert proto.stats()["sched_admitted_latency"] == 1
    assert proto.stats()["sched_wait_latency_p50_s"] == pytest.approx(0.5)


def test_stats_surface_keys():
    s = QoSScheduler()
    st = s.stats()
    for key in ("sched_preemptions", "sched_resumed_served",
                "sched_tokens_replayed", "sched_rejected_rate_limit",
                "sched_rejected_queue_full", "sched_expired_pending",
                "sched_queue_depth_latency", "sched_queue_depth_throughput",
                "sched_admitted_latency", "sched_wait_throughput_p95_s"):
        assert key in st


def test_remove_pulls_from_any_class_queue():
    s = QoSScheduler()
    s.enqueue(Item(1, Intent.CONTEXT), 0.0)
    s.enqueue(Item(2, Intent.INSIGHT), 0.0)
    assert s.remove(2)
    assert not s.remove(2)
    assert [it.seq_id for it in _pop_all(s, 2)] == [1]
