"""Runtime sanitizers as hard budgets: zero steady-state recompiles and
zero implicit device↔host transfers on the in-flight decode pump."""
import numpy as np
import pytest

from repro.analysis.sanitizers import (RecompileBudgetError,
                                       RecompileSanitizer, jit_roots,
                                       transfer_guard_ctx)
from repro.core import paper_lut
from repro.core.intent import Intent
from repro.engine import AveryEngine

LUT = paper_lut()


def _build_executor():
    from repro.configs.lisa_mini import CONFIG as PCFG
    from repro.core import DualStreamExecutor, profile as prof
    params, bns, _ = prof.random_init_system(PCFG, lut=LUT)
    return DualStreamExecutor(pcfg=PCFG, params=params, bottlenecks=bns,
                              lut=LUT, max_new_tokens=3,
                              flash_decode=False)


@pytest.fixture(scope="module")
def executor():
    return _build_executor()


@pytest.fixture()
def cold_executor():
    # the module-scoped executor's jit caches stay warm across tests;
    # cold-start compile behaviour needs its own
    return _build_executor()


def _engine(executor, **kw):
    # kv_pages pre-sizes the pool: growth mid-decode reallocates the KV
    # buffer and recompiles every paged stage (the churn class the
    # compile-budget test exists to pin)
    kw.setdefault("kv_pages", 64)
    kw.setdefault("max_prefixes", 8)
    return AveryEngine(lut=LUT, executor=executor, batching="inflight",
                       max_batch=4, **kw)


def _submit(engine, executor, k, sid, t):
    """Mixed-intent (Context/Insight) and mixed-tier traffic."""
    from repro.data import floodseg
    rng = np.random.RandomState(1000 + sid)
    kind = "any" if k % 3 == 2 else "segment"
    b = floodseg.make_batch(rng, 1, kind, augment=False)
    if kind == "any":
        pkt, _ = executor.edge_context(b["images"], sid, t)
        return engine.submit_packet(pkt, b["query"], Intent.CONTEXT,
                                    time_s=t)
    pkt = executor.edge_insight(b["images"], LUT.tiers[k % 2], sid, t)
    return engine.submit_packet(pkt, b["query"], Intent.INSIGHT, time_s=t)


# ---- compile budget: zero steady-state recompiles ----


def test_steady_state_compile_budget_is_zero(executor):
    """Warm a mixed-tier/mixed-intent in-flight batch, arm, then pump a
    second mixed batch for N steps: not one new jit trace."""
    engine = _engine(executor, debug_recompiles=True)
    futs = [_submit(engine, executor, i, i, float(i)) for i in range(6)]
    engine.drain()
    armed = engine.arm_sanitizers()
    assert armed and armed > 0              # warmup actually compiled

    futs = [_submit(engine, executor, i, 100 + i, 100.0 + i)
            for i in range(6)]
    for _ in range(20):
        engine.pump()                       # raises on any new compile
    engine.drain()
    assert all(f.done() for f in futs)
    assert engine.stats["new_compiles_since_arm"] == 0


def test_recompile_sanitizer_detects_churn(cold_executor):
    """Negative control: arm *before* warmup and the first real request
    must trip the budget — proving the census actually counts."""
    engine = _engine(cold_executor, debug_recompiles=True)
    engine.arm_sanitizers()
    with pytest.raises(RecompileBudgetError):
        _submit(engine, cold_executor, 0, 0, 0.0)
        for _ in range(50):
            engine.pump()
        engine.drain()


def test_jit_roots_discovery(cold_executor):
    """The census walks the executor's fixed jits and its keyed compile
    cache (dict values)."""
    engine = _engine(cold_executor)
    roots = jit_roots(engine)
    assert len(roots) >= 5                  # the executor's fixed jits
    assert all(callable(getattr(r, "_cache_size", None)) for r in roots)
    san = RecompileSanitizer(engine)
    before = san.compile_count()
    _submit(engine, cold_executor, 0, 0, 0.0)
    engine.drain()
    assert san.compile_count() > before     # first traffic compiles


def test_sanitizer_stats_and_noop_paths():
    """Host-only engine: sanitizer knobs are inert but well-formed."""
    class StubExecutor:
        buckets = (1,)
        max_new_tokens = 1
        num_compiled_stages = 0
    engine = AveryEngine(lut=LUT, executor=StubExecutor(),
                         debug_recompiles=True)
    assert engine.arm_sanitizers() == 0
    engine.check_sanitizers()               # no roots, no violation
    assert engine.stats["new_compiles_since_arm"] == 0
    plain = AveryEngine(lut=LUT, executor=StubExecutor())
    assert plain.arm_sanitizers() is None
    assert "new_compiles_since_arm" not in plain.stats


# ---- transfer guard: zero implicit transfers on the decode pump ----


def test_transfer_guard_actually_guards():
    """Sanity-check the guard semantics this jax provides: raw numpy
    into a jitted fn is an implicit h2d transfer and raises; an
    explicit jnp.asarray is allowed."""
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda v: v * 2)
    x = np.ones((4,), np.float32)
    f(jnp.asarray(x))                       # warm the trace
    with transfer_guard_ctx(True):
        f(jnp.asarray(x))                   # explicit: fine
        with pytest.raises(Exception):
            f(x)                            # implicit h2d: raises


def test_decode_pump_has_zero_implicit_transfers(executor):
    """The post-warmup pump runs entirely under
    jax.transfer_guard('disallow'): every device boundary crossing on
    the decode path is explicit."""
    engine = _engine(executor, debug_transfers=True)
    futs = [_submit(engine, executor, i, i, float(i)) for i in range(6)]
    for _ in range(20):
        engine.pump()                       # guarded: implicit raises
    engine.drain()                          # guarded drain
    assert all(f.done() for f in futs)
    # steady state stays clean too (fresh mixed batch, same guard)
    futs = [_submit(engine, executor, i, 50 + i, 50.0 + i)
            for i in range(4)]
    for _ in range(20):
        engine.pump()
    engine.drain()
    assert all(f.done() for f in futs)


def test_transfer_guard_with_speculation(executor):
    """The speculative path (draft prefill + paged verify) is also
    transfer-clean under the guard."""
    engine = _engine(executor, debug_transfers=True, speculative=True)
    futs = [_submit(engine, executor, 0, i, float(i)) for i in range(3)]
    for _ in range(30):
        engine.pump()
    engine.drain()
    assert all(f.done() for f in futs)
