"""The perf-regression gate: metric classification, the diff budgets
(direction + tolerance, zero-tolerance leaks, missing rows/metrics),
CLI exit codes, the baseline update round-trip, and the sha-stamped
history log."""
import copy
import importlib.util
import json
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "perf_gate", REPO / "scripts" / "perf_gate.py")
perf_gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(perf_gate)


BENCH = {
    "serving/alpha": {
        "us": 1000.0, "req_s": 150.0, "ttft_p50_s": 0.01,
        "ttft_p99_s": 0.5, "page_leaks": 0.0, "seed": 0.0,
        "delivered_under_slo": 0.96, "note": "free-text",
    },
    "serving/beta": {
        "us": 2000.0, "speedup_vs_paged": 1.4, "acceptance_rate": 0.8,
    },
}


def _dump(path, records):
    path.write_text(json.dumps(
        {"benchmark": "BENCH_serving", "records": records}, indent=2))
    return str(path)


# ---- classification ----


@pytest.mark.parametrize("metric,kind", [
    ("us", "lower"), ("ttft_p50_s", "lower"), ("wait_p95_us", "lower"),
    ("profile_overhead", "lower"),
    ("req_s", "higher"), ("delivered_under_slo", "higher"),
    ("jain", "higher"), ("speedup_vs_paged", "higher"),
    ("page_leaks", "zero"),
    ("seed", "ignore"), ("note", "ignore"), ("compile_events", "ignore"),
    ("ledger_flops_total", "ignore"), ("some_unknown_counter", "ignore"),
])
def test_classify(metric, kind):
    assert perf_gate.classify(metric) == kind


# ---- compare(): budgets and directions ----


def test_identical_bench_is_clean():
    regs, infos = perf_gate.compare(BENCH, BENCH, 0.5, 0.05)
    assert regs == [] and infos == []


def test_timing_regression_beyond_tolerance():
    bench = copy.deepcopy(BENCH)
    bench["serving/alpha"]["us"] = 10000.0          # 10x the baseline
    regs, _ = perf_gate.compare(bench, BENCH, 0.5, 0.05)
    assert len(regs) == 1 and "serving/alpha.us" in regs[0]
    # ...but within the budget it's noise, not a regression
    bench["serving/alpha"]["us"] = 1400.0           # +40% < +50%
    regs, _ = perf_gate.compare(bench, BENCH, 0.5, 0.05)
    assert regs == []


def test_quality_drop_beyond_tolerance():
    bench = copy.deepcopy(BENCH)
    bench["serving/alpha"]["delivered_under_slo"] = 0.5
    bench["serving/beta"]["acceptance_rate"] = 0.79  # -1.25% < -5%
    regs, _ = perf_gate.compare(bench, BENCH, 0.5, 0.05)
    assert len(regs) == 1
    assert "serving/alpha.delivered_under_slo" in regs[0]


def test_speedup_rides_the_time_tolerance():
    bench = copy.deepcopy(BENCH)
    bench["serving/beta"]["speedup_vs_paged"] = 1.0  # -29%: inside +-50%
    regs, _ = perf_gate.compare(bench, BENCH, 0.5, 0.05)
    assert regs == []
    bench["serving/beta"]["speedup_vs_paged"] = 0.6  # -57%: beyond
    regs, _ = perf_gate.compare(bench, BENCH, 0.5, 0.05)
    assert len(regs) == 1 and "speedup_vs_paged" in regs[0]


def test_speedup_parity_floor_gates_hard():
    # baseline claims a 1.4x win: a recorded value below 1.0 means the
    # fast path measured slower than its own in-run baseline — gated
    # even inside the loose smoke time tolerance (which would otherwise
    # admit anything down to 1.4 * (1 - 1.5) < 0)
    bench = copy.deepcopy(BENCH)
    bench["serving/beta"]["speedup_vs_paged"] = 0.95
    regs, _ = perf_gate.compare(bench, BENCH, 1.5, 0.30)
    assert len(regs) == 1 and "below parity" in regs[0]
    # at parity or above, the relative budget alone governs
    bench["serving/beta"]["speedup_vs_paged"] = 1.0
    regs, _ = perf_gate.compare(bench, BENCH, 1.5, 0.30)
    assert regs == []


def test_near_parity_speedup_baseline_skips_the_floor():
    # a row whose baseline never claimed a material win (the
    # CPU-container spec-decode row sits near 1.0 by design: the draft
    # shares the target's geometry) must not flap CI on noise dipping
    # below 1.0...
    base = copy.deepcopy(BENCH)
    base["serving/beta"]["speedup_vs_paged"] = 1.01
    bench = copy.deepcopy(base)
    bench["serving/beta"]["speedup_vs_paged"] = 0.79
    regs, _ = perf_gate.compare(bench, base, 1.5, 0.30)
    assert regs == []
    # ...though the relative time budget still bounds the fall
    bench["serving/beta"]["speedup_vs_paged"] = 0.2
    regs, _ = perf_gate.compare(bench, base, 0.5, 0.05)
    assert len(regs) == 1 and "speedup_vs_paged" in regs[0]


def test_page_leak_is_zero_tolerance():
    bench = copy.deepcopy(BENCH)
    bench["serving/alpha"]["page_leaks"] = 1.0
    regs, _ = perf_gate.compare(bench, BENCH, 100.0, 1.0)
    assert len(regs) == 1 and "page_leaks" in regs[0]


def test_missing_row_and_metric_are_regressions():
    bench = copy.deepcopy(BENCH)
    del bench["serving/beta"]
    del bench["serving/alpha"]["ttft_p99_s"]
    regs, _ = perf_gate.compare(bench, BENCH, 0.5, 0.05)
    assert any("serving/beta: row missing" in r for r in regs)
    assert any("ttft_p99_s: metric missing" in r for r in regs)
    # ignored metrics going missing is fine (they were never gated)
    bench2 = copy.deepcopy(BENCH)
    del bench2["serving/alpha"]["seed"]
    regs, _ = perf_gate.compare(bench2, BENCH, 0.5, 0.05)
    assert regs == []


def test_new_rows_and_metrics_are_informational():
    bench = copy.deepcopy(BENCH)
    bench["serving/gamma"] = {"us": 5.0}
    bench["serving/alpha"]["thr_p50_s"] = 1.0
    regs, infos = perf_gate.compare(bench, BENCH, 0.5, 0.05)
    assert regs == []
    assert any("serving/gamma: new row" in i for i in infos)
    assert any("thr_p50_s: new metric" in i for i in infos)


# ---- the CLI: exit codes, baseline round-trip, history ----


def test_main_exit_codes(tmp_path, capsys):
    bench = _dump(tmp_path / "bench.json", BENCH)
    base = _dump(tmp_path / "base.json", BENCH)
    assert perf_gate.main(["--bench", bench, "--baseline", base]) == 0
    assert "clean" in capsys.readouterr().out
    worse = copy.deepcopy(BENCH)
    worse["serving/alpha"]["us"] = 10000.0
    bad = _dump(tmp_path / "bad.json", worse)
    assert perf_gate.main(["--bench", bad, "--baseline", base]) == 1
    assert "REGRESSION" in capsys.readouterr().out
    # a raised tolerance admits the same diff
    assert perf_gate.main(["--bench", bad, "--baseline", base,
                           "--tolerance", "10.0"]) == 0
    capsys.readouterr()
    missing = str(tmp_path / "nope.json")
    assert perf_gate.main(["--bench", missing, "--baseline", base]) == 2
    assert perf_gate.main(["--bench", bench, "--baseline", missing]) == 2


def test_update_baseline_roundtrip(tmp_path, capsys):
    worse = copy.deepcopy(BENCH)
    worse["serving/alpha"]["us"] = 10000.0
    bench = _dump(tmp_path / "bench.json", worse)
    base = str(tmp_path / "base.json")
    # an intentional perf change: admit the new numbers, gate is clean
    assert perf_gate.main(["--bench", bench, "--baseline", base,
                           "--update-baseline"]) == 0
    capsys.readouterr()
    assert perf_gate.main(["--bench", bench, "--baseline", base]) == 0
    written = json.loads(Path(base).read_text())
    assert written["records"]["serving/alpha"]["us"] == 10000.0


def test_json_report_and_history(tmp_path, capsys):
    bench = _dump(tmp_path / "bench.json", BENCH)
    base = _dump(tmp_path / "base.json", BENCH)
    hist = tmp_path / "hist.jsonl"
    assert perf_gate.main(["--bench", bench, "--baseline", base,
                           "--json", "--append-history", str(hist)]) == 0
    report = json.loads(capsys.readouterr().out)
    assert report["ok"] is True and report["regressions"] == []
    # history appends one parseable sha-stamped entry per run
    assert perf_gate.main(["--bench", bench, "--baseline", base,
                           "--append-history", str(hist)]) == 0
    capsys.readouterr()
    lines = hist.read_text().splitlines()
    assert len(lines) == 2
    for line in lines:
        entry = json.loads(line)
        assert entry["sha"] and entry["time_utc"]
        assert entry["records"] == BENCH


def test_write_bench_json_seeds_merge_from_committed_mirror(tmp_path,
                                                            monkeypatch):
    """A fresh checkout has no ``benchmarks/artifacts/`` bench file but
    does have the committed root mirror: a partial (smoke) run must
    merge into the tracked trajectory, not clobber it down to its own
    rows — the gate treats a vanished row as a regression, so the
    merge base is load-bearing for CI on clean clones."""
    spec = importlib.util.spec_from_file_location(
        "bench_common", REPO / "benchmarks" / "common.py")
    common = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(common)
    art = tmp_path / "repo" / "benchmarks" / "artifacts"
    monkeypatch.setattr(common, "ART", str(art))
    mirror = tmp_path / "repo" / "BENCH_serving.json"
    mirror.parent.mkdir(parents=True)
    _dump(mirror, {"serving/full": {"us": 9.0, "req_s": 100.0}})
    common.write_bench_json(["serving/smoke,5,req_s=42.0"])
    merged = json.loads(mirror.read_text())["records"]
    assert set(merged) == {"serving/full", "serving/smoke"}
    assert merged["serving/smoke"]["req_s"] == 42.0
    # once the artifact exists it is the merge base (and wins over the
    # now-stale mirror): a second run updates its row in place
    common.write_bench_json(["serving/smoke,5,req_s=43.0"])
    merged = json.loads(mirror.read_text())["records"]
    assert set(merged) == {"serving/full", "serving/smoke"}
    assert merged["serving/smoke"]["req_s"] == 43.0


def test_committed_baseline_gates_committed_bench(capsys):
    """The repo's own artifacts: the committed bench must pass the
    committed baseline under the full-run budgets (CI runs the smoke
    budgets, so this is the stricter check)."""
    rc = perf_gate.main(["--bench", str(REPO / "BENCH_serving.json"),
                         "--baseline", str(REPO / "BENCH_baseline.json")])
    out = capsys.readouterr().out
    assert rc == 0, f"committed bench regresses committed baseline:\n{out}"
