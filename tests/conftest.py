import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benchmarks must see the real single CPU device; only the dry-run
# entrypoint (repro.launch.dryrun) requests 512 placeholder devices.
