"""The §Perf optimization levers must be numerically equivalent to the
paper-faithful baselines (they change layout/scheduling, not math)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (ModelConfig, MoEConfig, forward, init_cache,
                          decode_step, init_params)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = ModelConfig(
        name="t", arch_type="moe", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=97,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                      capacity_factor=8.0))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                          0, 97)}
    base, *_ = forward(params, cfg, batch)
    return cfg, params, batch, base


@pytest.mark.parametrize("dispatch", ["scatter", "grouped"])
def test_moe_dispatch_equivalence(moe_setup, dispatch):
    cfg, params, batch, base = moe_setup
    c2 = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch=dispatch))
    out, *_ = forward(params, c2, batch)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=1e-4, atol=1e-4)


def test_attn_chunk_equivalence():
    cfg = ModelConfig(name="t", arch_type="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, 97)}
    base, *_ = forward(params, cfg, batch)
    out, *_ = forward(params, cfg.replace(attn_chunk=8), batch)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=1e-4, atol=1e-4)


def test_attn_chunk_equivalence_mla():
    from repro.models import MLAConfig
    cfg = ModelConfig(name="t", arch_type="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=97,
                      attn_type="mla",
                      mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                    qk_nope_head_dim=16, qk_rope_head_dim=8,
                                    v_head_dim=16))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, 97)}
    base, *_ = forward(params, cfg, batch)
    out, *_ = forward(params, cfg.replace(attn_chunk=8), batch)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=1e-4, atol=1e-4)


def test_seq_shard_and_kvhd_are_noops_without_mesh():
    """wsc-based levers are identity off-mesh (single-device tests/serving)."""
    cfg = ModelConfig(name="t", arch_type="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, 97)}
    base, *_ = forward(params, cfg, batch)
    out, *_ = forward(params, cfg.replace(seq_shard=True), batch)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))

    cache = init_cache(cfg, 2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    l1, _ = decode_step(params, cfg, cache, tok, jnp.int32(0))
    l2, _ = decode_step(params, cfg.replace(shard_cache_hd=True), cache, tok,
                        jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_kvhd_decode_consistency_with_mesh():
    """shard_cache_hd decode on a (1,1) mesh matches the unsharded path."""
    cfg = ModelConfig(name="t", arch_type="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                      shard_cache_hd=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 97)
    base, *_ = forward(params, cfg, {"tokens": tokens})
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cache = init_cache(cfg, 2, 8)
    outs = []
    with mesh:
        for t in range(8):
            lg, cache = decode_step(params, cfg, cache, tokens[:, t:t + 1],
                                    jnp.int32(t))
            outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(base),
                               rtol=1e-4, atol=1e-4)
