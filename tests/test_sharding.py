"""Sharding-spec rules: every spec must be structurally valid for its
tensor (rank match + divisibility) across all 10 architectures and all
cache/batch trees; a reduced train step must lower under a mesh; and
the *serving* rules (paged pool / page tables / logits) plus the
sharded serving context must reproduce the unsharded path token-exact."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import optim
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.launch.dryrun import SHAPES, abstract_cache, abstract_params, \
    adapt_config, input_specs
from repro.models import make_train_step
from repro.sharding import specs as sh


def _abstract_mesh(sizes, names):
    """AbstractMesh across jax API drift: older versions take
    (sizes, names), 0.4.37+ takes ((name, size), ...) pairs."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(sizes, names)


def fake_mesh():
    """Abstract 16x16 mesh for spec validation (no devices needed)."""
    return _abstract_mesh((16, 16), ("data", "model"))


def fake_mesh_multipod():
    return _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _check_tree(specs, tree, mesh):
    for (path, spec), (_, leaf) in zip(
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0],
            jax.tree_util.tree_flatten_with_path(tree)[0]):
        assert isinstance(spec, P), (path, spec)
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (path, spec, leaf.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_fn", [fake_mesh, fake_mesh_multipod])
def test_param_specs_valid(arch, mesh_fn):
    cfg = get_config(arch)
    mesh = mesh_fn()
    aparams = abstract_params(cfg)
    _check_tree(sh.param_specs(cfg, aparams, mesh), aparams, mesh)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", ["decode_32k", "long_500k"])
def test_cache_specs_valid(arch, shape):
    cfg = adapt_config(get_config(arch), SHAPES[shape])
    if cfg is None:
        pytest.skip("combo skipped by design")
    mesh = fake_mesh()
    acache = abstract_cache(cfg, SHAPES[shape])
    _check_tree(sh.cache_specs(cfg, acache, mesh), acache, mesh)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_batch_specs_valid(arch):
    cfg = get_config(arch)
    mesh = fake_mesh()
    batch = input_specs(cfg, SHAPES["train_4k"])
    _check_tree(sh.batch_specs(batch, mesh), batch, mesh)


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "granite-moe-3b-a800m",
                                  "falcon-mamba-7b", "zamba2-7b"])
def test_reduced_train_step_lowers_on_local_mesh(arch):
    """End-to-end jit lowering with NamedShardings on the (1,1) local mesh
    — catches spec/structure mismatches that AbstractMesh checks miss."""
    cfg = get_reduced(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    aparams = abstract_params(cfg)
    pspecs = sh.param_specs(cfg, aparams, mesh)
    psh = sh.to_shardings(mesh, pspecs)
    opt = optim.adamw(1e-3)
    aopt = jax.eval_shape(opt.init, aparams)
    osh = sh.to_shardings(mesh, sh.opt_state_specs(cfg, aopt, pspecs, mesh))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
    bsh = sh.to_shardings(mesh, sh.batch_specs(batch, mesh))
    fn = jax.jit(make_train_step(cfg, opt), in_shardings=(psh, osh, bsh))
    with mesh:
        lowered = fn.lower(aparams, aopt, batch)
    assert lowered is not None


# ---- serving specs: paged pool / page tables / logits rules ----


def _sds(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def test_serving_specs_shard_kv_heads_replicate_pages():
    """Paged-pool and paged-prefix KV leaves shard the kv-heads axis
    (second-to-last) over "model"; the page/batch axes replicate, so
    page-table gathers are shard-local."""
    mesh = fake_mesh()                       # (data=16, model=16)
    tree = {"groups": [{
        "k": _sds((4, 64, 16, 32, 8)),       # pool (L, P, page, K, hd)
        "v": _sds((4, 64, 16, 32, 8)),
    }]}
    out = sh.serving_specs(tree, mesh)
    assert out["groups"][0]["k"] == P(None, None, None, "model", None)
    assert out["groups"][0]["v"] == P(None, None, None, "model", None)
    # paged prefix with batch axis (L, B, n_pages, page, K, hd): same rule
    pre = sh.serving_specs({"k": _sds((4, 1, 3, 16, 32, 8))}, mesh)
    assert pre["k"] == P(None, None, None, None, "model", None)
    # draft ring cache (L, B, W, K, hd)
    ring = sh.serving_specs({"k": _sds((4, 8, 64, 32, 8))}, mesh)
    assert ring["k"] == P(None, None, None, "model", None)


def test_serving_specs_fall_back_and_replicate_host_state():
    """Non-divisible kv-heads replicate; page tables, positions, token
    ids, logits and per-row scalars always replicate."""
    mesh = fake_mesh()
    out = sh.serving_specs({
        "groups": [{"k": _sds((4, 64, 16, 6, 8)),    # K=6 % 16 != 0
                    "v": _sds((4, 64, 16, 6, 8))}],
        "page_table": jax.ShapeDtypeStruct((8, 6), jnp.int32),
        "positions": jax.ShapeDtypeStruct((8, 96), jnp.int32),
        "tokens": jax.ShapeDtypeStruct((8, 1), jnp.int32),
        "logits": _sds((8, 32000)),
        "pos": jax.ShapeDtypeStruct((8,), jnp.int32),
    }, mesh)
    assert out["groups"][0]["k"] == P(None, None, None, None, None)
    assert out["page_table"] == P(None, None)
    assert out["positions"] == P(None, None)
    assert out["tokens"] == P(None, None)
    assert out["logits"] == P(None, None)
    assert out["pos"] == P(None)


def test_make_local_mesh_clamps_oversized_model_axis():
    """Regression: asking for more model shards than the host has
    devices used to build an empty (0, k) mesh; now it clamps to the
    device count (and rejects non-divisors with a clear error)."""
    from repro.launch.mesh import make_local_mesh
    n = len(jax.devices())
    mesh = make_local_mesh(model=8 * n)
    assert mesh.size == n
    assert mesh.shape["model"] >= 1 and mesh.shape["data"] >= 1
    with pytest.raises(ValueError):
        make_local_mesh(model=0)


# ---- sharded serving context: end-to-end exactness ----


@pytest.fixture(scope="module")
def serving_executor():
    from repro.configs.lisa_mini import CONFIG as PCFG
    from repro.core import DualStreamExecutor, paper_lut, profile as prof
    lut = paper_lut()
    params, bns, _ = prof.random_init_system(PCFG, lut=lut)
    return DualStreamExecutor(pcfg=PCFG, params=params, bottlenecks=bns,
                              lut=lut, max_new_tokens=3,
                              flash_decode=False, page_size=4)


def test_sharded_context_token_exact_on_local_mesh(serving_executor):
    """ShardedServingContext + mesh-resident PagePool over the local
    mesh (degenerate 1x1 on this host): the whole machinery —
    device_put params, explicit in/out shardings, pool placement on
    ensure/growth, sharded draft fns, residency stats — serves
    token-exact vs the unsharded one-shot generate path, for paged
    decode and for speculative verify. The assertions live in the
    module's own selftest (one source of truth with the 1x2 subprocess
    pin below)."""
    from repro.sharding import serving

    serving._selftest(model=1, executor=serving_executor)


def test_sharded_decode_and_verify_token_exact_on_1x2_mesh():
    """The real thing: a 1x2 host-platform mesh (2 forced CPU devices,
    model=2 -> kv-heads genuinely split across shards). Device count
    must be forced *before* any jax import, so this runs the module
    selftest in a subprocess; the selftest asserts sharded paged decode
    and sharded speculative verify token-exact vs unsharded
    ``llm_generate`` and prints the pinned summary line."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    res = subprocess.run(
        [sys.executable, "-m", "repro.sharding.serving", "--model=2"],
        capture_output=True, text=True, env=env, cwd=root, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "token-exact" in res.stdout
    assert "'model': 2" in res.stdout
