"""Sharding-spec rules: every spec must be structurally valid for its
tensor (rank match + divisibility) across all 10 architectures and all
cache/batch trees; and a reduced train step must lower under a mesh."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import optim
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.launch.dryrun import SHAPES, abstract_cache, abstract_params, \
    adapt_config, input_specs
from repro.models import make_train_step
from repro.sharding import specs as sh


def _abstract_mesh(sizes, names):
    """AbstractMesh across jax API drift: older versions take
    (sizes, names), 0.4.37+ takes ((name, size), ...) pairs."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(sizes, names)


def fake_mesh():
    """Abstract 16x16 mesh for spec validation (no devices needed)."""
    return _abstract_mesh((16, 16), ("data", "model"))


def fake_mesh_multipod():
    return _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _check_tree(specs, tree, mesh):
    for (path, spec), (_, leaf) in zip(
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0],
            jax.tree_util.tree_flatten_with_path(tree)[0]):
        assert isinstance(spec, P), (path, spec)
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (path, spec, leaf.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_fn", [fake_mesh, fake_mesh_multipod])
def test_param_specs_valid(arch, mesh_fn):
    cfg = get_config(arch)
    mesh = mesh_fn()
    aparams = abstract_params(cfg)
    _check_tree(sh.param_specs(cfg, aparams, mesh), aparams, mesh)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", ["decode_32k", "long_500k"])
def test_cache_specs_valid(arch, shape):
    cfg = adapt_config(get_config(arch), SHAPES[shape])
    if cfg is None:
        pytest.skip("combo skipped by design")
    mesh = fake_mesh()
    acache = abstract_cache(cfg, SHAPES[shape])
    _check_tree(sh.cache_specs(cfg, acache, mesh), acache, mesh)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_batch_specs_valid(arch):
    cfg = get_config(arch)
    mesh = fake_mesh()
    batch = input_specs(cfg, SHAPES["train_4k"])
    _check_tree(sh.batch_specs(batch, mesh), batch, mesh)


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "granite-moe-3b-a800m",
                                  "falcon-mamba-7b", "zamba2-7b"])
def test_reduced_train_step_lowers_on_local_mesh(arch):
    """End-to-end jit lowering with NamedShardings on the (1,1) local mesh
    — catches spec/structure mismatches that AbstractMesh checks miss."""
    cfg = get_reduced(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    aparams = abstract_params(cfg)
    pspecs = sh.param_specs(cfg, aparams, mesh)
    psh = sh.to_shardings(mesh, pspecs)
    opt = optim.adamw(1e-3)
    aopt = jax.eval_shape(opt.init, aparams)
    osh = sh.to_shardings(mesh, sh.opt_state_specs(cfg, aopt, pspecs, mesh))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
    bsh = sh.to_shardings(mesh, sh.batch_specs(batch, mesh))
    fn = jax.jit(make_train_step(cfg, opt), in_shardings=(psh, osh, bsh))
    with mesh:
        lowered = fn.lower(aparams, aopt, batch)
    assert lowered is not None
