"""Multi-UAV fleet extension (beyond-paper, EXPERIMENTS §Beyond-paper)."""
from repro.core import paper_lut
from repro.network import constant_trace, paper_trace
from repro.runtime.fleet import run_fleet
from repro.runtime.mission import MissionSpec

LUT = paper_lut()


def test_fleet_shares_bandwidth():
    """Per-UAV throughput at N=2 is roughly half the N=1 throughput when
    link-bound (constant 10 Mbps: Balanced tier, tx-limited)."""
    one = run_fleet(LUT, constant_trace(10.0, 600), 1,
                    MissionSpec(duration_s=600, mode="avery"))
    two = run_fleet(LUT, constant_trace(10.0, 600), 2,
                    MissionSpec(duration_s=600, mode="avery"))
    per_uav = two.aggregate_pps / 2
    assert per_uav < one.aggregate_pps
    assert two.aggregate_pps > one.aggregate_pps * 0.8  # aggregate holds up


def test_strict_controller_starves_at_scale():
    """At N=6 on the paper trace no tier meets F_I at a 1/6 share for most
    of the mission — the fleet-scale failure mode of hard feasibility."""
    fleet = run_fleet(LUT, paper_trace(seed=0), 6,
                      MissionSpec(mode="avery"))
    assert fleet.infeasible_frac > 0.5


def test_fallback_restores_liveness():
    strict = run_fleet(LUT, paper_trace(seed=0), 6,
                       MissionSpec(mode="avery"))
    fb = run_fleet(LUT, paper_trace(seed=0), 6,
                   MissionSpec(mode="avery", fallback=True))
    assert fb.aggregate_pps > 10 * strict.aggregate_pps
    assert fb.infeasible_frac > 0.2       # still reported, just not idle
    # fidelity cost is bounded by the lightest tier's accuracy
    lightest = min(LUT.tiers, key=lambda t: t.payload_mb)
    assert fb.mean_iou > lightest.acc_base - 0.02
