"""Bottleneck compression + depth-wise split invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # minimal envs: seeded-sampling fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import BottleneckSpec, SplitPlan, init_bottleneck, \
    rank_for_ratio
from repro.core import bottleneck as bn
from repro.models import ModelConfig, SSMConfig, forward, init_params
from repro.models.common import causal_mask


def test_rank_for_ratio_paper_geometry():
    """Paper Fig. 5: 10.49 MB SAM activation (4096 x 1280 x bf16); the
    r=0.25 tier payload must come out ~2.6 MB of codes."""
    rank = rank_for_ratio(1280, 0.25, 2)
    payload = 4096 * rank / 1e6
    assert 2.3 < payload < 2.7


@given(ratio=st.floats(0.02, 0.6), d=st.sampled_from([64, 128, 1280, 4096]))
@settings(max_examples=60, deadline=None)
def test_ratio_roundtrip(ratio, d):
    rank = rank_for_ratio(d, ratio, 2)
    spec = BottleneckSpec(d, rank, 2)
    assert abs(spec.ratio - ratio) < 0.05 or rank in (1, d)


@given(seed=st.integers(0, 100), rank=st.sampled_from([8, 32, 64]))
@settings(max_examples=20, deadline=None)
def test_quantisation_bounds(seed, rank):
    """Codes are always within [-127, 127]; dequantised codes reconstruct
    the projection within the quantisation step (hypothesis property)."""
    rng = jax.random.PRNGKey(seed)
    x = jax.random.normal(rng, (32, 64)) * 10.0
    p = init_bottleneck(jax.random.PRNGKey(seed + 1),
                        BottleneckSpec(64, rank, 4))
    codes, scales = bn.encode(p, x)
    assert int(jnp.max(jnp.abs(codes.astype(jnp.int32)))) <= 127
    z = x @ p["enc"]
    z_hat = codes.astype(jnp.float32) * scales
    assert float(jnp.max(jnp.abs(z - z_hat))) <= float(jnp.max(scales)) * 0.51


def test_higher_rank_reconstructs_better():
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (256, 128))
    errs = []
    for rank in (8, 32, 96):
        p = init_bottleneck(jax.random.PRNGKey(1), BottleneckSpec(128, rank, 4))
        # use PCA-free random projection: error should still shrink with rank
        codes, scales = bn.encode(p, x)
        xh = bn.decode(p, codes, scales)
        # compare against best linear reconstruction via lstsq for fairness
        errs.append(float(jnp.mean(jnp.square(
            xh - x @ p["enc"] @ p["dec"]))))
    assert errs[2] <= errs[0] + 1e-3   # quantisation noise shrinks with rank


def test_straight_through_gradients_flow():
    p = init_bottleneck(jax.random.PRNGKey(0), BottleneckSpec(32, 8, 4))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 32))

    def loss(p):
        return jnp.mean(jnp.square(bn.roundtrip_st(p, x) - x))

    g = jax.grad(loss)(p)
    assert all(float(jnp.max(jnp.abs(l))) > 0 for l in jax.tree.leaves(g))


# ------------------------------ split --------------------------------------


@pytest.mark.parametrize("cfg,k", [
    (ModelConfig(name="d", arch_type="dense", num_layers=4, d_model=64,
                 num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97), 1),
    (ModelConfig(name="s", arch_type="ssm", num_layers=4, d_model=64,
                 num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=97,
                 attn_type="none", rope_style="none",
                 ssm=SSMConfig(version=1, state_size=4)), 2),
])
def test_split_head_tail_equals_full(cfg, k):
    """head_apply + tail_apply over the boundary activation reproduces the
    monolithic forward exactly (the paper's split@k is lossless without
    the bottleneck)."""
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    logits_full, _, _, hidden_full = forward(params, cfg, {"tokens": tokens})

    plan = SplitPlan(cfg, k)
    edge, cloud = plan.split_params(params)
    x = jnp.take(params["embed"], tokens, axis=0)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    mask = causal_mask(S)[None]
    a = plan.head_apply(edge, x, positions, mask)
    h = plan.tail_apply(cloud, a, positions, mask)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hidden_full),
                               rtol=1e-5, atol=1e-5)


def test_split_params_partition_is_exact():
    """Every group layer lands on exactly one side."""
    cfg = ModelConfig(name="d", arch_type="dense", num_layers=6, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=31)
    params = init_params(cfg, jax.random.PRNGKey(0))
    for k in range(1, 6):
        plan = SplitPlan(cfg, k)
        edge, cloud = plan.split_params(params)
        n_head = edge["groups"][0]["attn"]["wq"].shape[0]
        n_tail = cloud["groups"][0]["attn"]["wq"].shape[0]
        assert n_head == k and n_tail == 6 - k
