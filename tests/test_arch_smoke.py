"""Per-architecture smoke tests (assignment requirement f): a REDUCED
variant of each assigned family runs one forward/train step on CPU with
correct output shapes and no NaNs; decoders also run one decode step."""
import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import (decode_step, forward, init_cache, init_params,
                          make_train_step)

from helpers import make_batch

B, S = 2, 16


@pytest.fixture(scope="module")
def trained_state():
    return {}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S)
    logits, aux, _, hidden = jax.jit(
        lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(logits))), f"NaN logits for {arch}"

    opt = optim.adamw(1e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    params2, state2, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["total_loss"])), metrics
    # params actually changed
    delta = sum(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_reduced(arch)
    if not cfg.supports_decode:
        pytest.skip("encoder-only arch has no decode step (by design)")
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, B, S)
    tokens = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t, pos: decode_step(p, cfg, c, t, pos)
    )(params, cache, tokens, jnp.int32(3))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["nemotron-4-340b", "qwen1.5-32b"])
def test_sliding_window_variant(arch):
    """long_500k path for dense archs uses the sliding-window variant."""
    cfg = get_reduced(arch).with_sliding_window(8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S)
    logits, *_ = forward(params, cfg, batch)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # ring-buffer decode at a position far beyond the window
    cache = init_cache(cfg, B, 8)
    logits, _ = decode_step(params, cfg, cache, jnp.zeros((B, 1), jnp.int32),
                            jnp.int32(1000))
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_full_configs_match_assignment():
    expect = {
        "falcon-mamba-7b": (64, 4096, 65024),
        "nemotron-4-340b": (96, 18432, 256000),
        "qwen1.5-32b": (64, 5120, 152064),
        "phi4-mini-3.8b": (32, 3072, 200064),
        "zamba2-7b": (81, 3584, 32000),
        "hubert-xlarge": (48, 1280, 504),
        "granite-moe-3b-a800m": (32, 1536, 49155),
        "deepseek-v3-671b": (61, 7168, 129280),
        "minicpm3-4b": (62, 2560, 73448),
        "qwen2-vl-2b": (28, 1536, 151936),
    }
    for arch, (L, d, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.vocab_size) == (L, d, v), arch


def test_param_counts_plausible():
    """Sanity-pin the analytic parameter counts to the model names."""
    import repro.models.stack as stack
    assert abs(stack.count_params(get_config("deepseek-v3-671b")) / 1e9
               - 671) < 10
    assert abs(stack.count_params(get_config("deepseek-v3-671b"),
                                  active_only=True) / 1e9 - 37.9) < 2
    assert abs(stack.count_params(get_config("nemotron-4-340b")) / 1e9
               - 341) < 10
    assert abs(stack.count_params(get_config("falcon-mamba-7b")) / 1e9
               - 7.3) < 1
