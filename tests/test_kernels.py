"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
pure-jnp ref.py oracles (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bottleneck import ops as bops
from repro.kernels.bottleneck import ref as bref
from repro.kernels.flash_attention import ops as fops
from repro.kernels.flash_attention import ref as fref
from repro.kernels.ssm_scan import ops as sops
from repro.kernels.ssm_scan import ref as sref


# --------------------------- bottleneck -----------------------------------


@pytest.mark.parametrize("T,d,r", [(128, 128, 32), (64, 256, 100),
                                   (100, 64, 16), (256, 1280, 638)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bottleneck_encode(T, d, r, dtype):
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (T, d), dtype)
    w = (jax.random.normal(jax.random.fold_in(rng, 1), (d, r)) * 0.05
         ).astype(dtype)
    codes, scales = bops.bottleneck_encode(x, w)
    codes_r, scales_r = bref.encode_ref(x, w)
    assert codes.dtype == jnp.int8
    # matmul accumulation-order differences can flip a round() at .5:
    # codes agree within +-1 and scales to fp tolerance
    np.testing.assert_allclose(np.asarray(scales), np.asarray(scales_r),
                               rtol=1e-5, atol=1e-7)
    diff = np.abs(np.asarray(codes, np.int32) - np.asarray(codes_r, np.int32))
    assert diff.max() <= 1
    assert (diff > 0).mean() < 1e-3


@pytest.mark.parametrize("T,d,r", [(128, 128, 32), (64, 256, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bottleneck_decode(T, d, r, dtype):
    rng = jax.random.PRNGKey(0)
    codes = jax.random.randint(rng, (T, r), -127, 128).astype(jnp.int8)
    scales = jax.random.uniform(rng, (T, 1), minval=0.01, maxval=0.1)
    w = (jax.random.normal(rng, (r, d)) * 0.05).astype(dtype)
    out = bops.bottleneck_decode(codes, scales, w, out_dtype=jnp.float32)
    out_r = bref.decode_ref(codes, scales, w, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-4)


def test_bottleneck_batched_shapes():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 37, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 16)) * 0.1
    codes, scales = bops.bottleneck_encode(x, w)
    assert codes.shape == (2, 37, 16) and scales.shape == (2, 37, 1)
    wd = jax.random.normal(jax.random.PRNGKey(2), (16, 64)) * 0.1
    y = bops.bottleneck_decode(codes, scales, wd)
    assert y.shape == (2, 37, 64)


# ------------------------- flash attention --------------------------------


@pytest.mark.parametrize("B,S,H,K,hd", [(2, 128, 4, 2, 64), (1, 200, 4, 4, 32),
                                        (2, 64, 8, 2, 64), (1, 256, 4, 1, 128),
                                        (1, 96, 6, 3, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, S, H, K, hd, causal):
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, K, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 3), (B, S, K, hd))
    out = fops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = fref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (1, 128, 4, 64), dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 128, 2, 64), dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (1, 128, 2, 64), dtype)
    out = fops.flash_attention(q, k, v, causal=True)
    ref = fref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------- ssm scan ------------------------------------


@pytest.mark.parametrize("B,S,C,N", [(2, 64, 128, 16), (1, 100, 60, 8),
                                     (2, 128, 256, 4), (1, 33, 16, 16)])
def test_ssm_scan_matches_ref(B, S, C, N):
    rng = jax.random.PRNGKey(0)
    decay = jax.random.uniform(jax.random.fold_in(rng, 1), (B, S, C, N),
                               minval=0.5, maxval=1.0)
    drive = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, C, N)) * 0.1
    h = sops.chunked_scan(decay, drive, chunk=32, block_c=64)
    h_ref = sref.scan_ref(decay, drive)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-6)


def test_ssm_scan_long_decay_stability():
    """Long-sequence stability: products of 512 decays stay finite and match
    the associative-scan oracle."""
    rng = jax.random.PRNGKey(7)
    decay = jax.random.uniform(rng, (1, 512, 32, 8), minval=0.9, maxval=0.999)
    drive = jax.random.normal(jax.random.fold_in(rng, 1), (1, 512, 32, 8))
    h = sops.chunked_scan(decay, drive, chunk=64, block_c=32)
    h_ref = sref.scan_ref(decay, drive)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


# ------------------------- decode attention --------------------------------


@pytest.mark.parametrize("B,H,K,hd,W", [(2, 4, 2, 64, 128), (1, 8, 8, 32, 200),
                                        (2, 8, 1, 128, 96), (4, 4, 4, 64, 512)])
def test_decode_attention_matches_ref(B, H, K, hd, W):
    from repro.kernels.decode_attention import ops as dops
    from repro.kernels.decode_attention import ref as dref
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(jax.random.fold_in(rng, 1), (B, H, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 2), (B, W, K, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 3), (B, W, K, hd))
    # slot-validity mask: ragged per-batch lengths (ring-buffer semantics)
    lens = np.linspace(W // 2, W, B).astype(int)
    bias = np.zeros((B, W), np.float32)
    for i, L in enumerate(lens):
        bias[i, L:] = -1e30
    bias = jnp.asarray(bias)
    out = dops.decode_attention(q, k, v, bias, block_k=64)
    ref = dref.decode_attention_ref(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=2e-5)


def test_decode_attention_bf16():
    from repro.kernels.decode_attention import ops as dops
    from repro.kernels.decode_attention import ref as dref
    rng = jax.random.PRNGKey(1)
    q = jax.random.normal(rng, (2, 4, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 128, 2, 64),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 128, 2, 64),
                          jnp.bfloat16)
    bias = jnp.zeros((2, 128), jnp.float32)
    out = dops.decode_attention(q, k, v, bias)
    ref = dref.decode_attention_ref(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("B,H,K,hd,P,page,n",
                         [(2, 4, 2, 64, 9, 16, 3), (1, 8, 8, 32, 5, 8, 4),
                          (3, 4, 1, 128, 12, 32, 2)])
def test_paged_decode_attention_matches_ref(B, H, K, hd, P, page, n):
    """Page-table gather path == dense oracle over the gathered layout."""
    from repro.kernels.decode_attention import ops as dops
    from repro.kernels.decode_attention import ref as dref
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, H, hd), jnp.float32)
    kp = jnp.asarray(rng.randn(P, page, K, hd), jnp.float32)
    vp = jnp.asarray(rng.randn(P, page, K, hd), jnp.float32)
    pt = jnp.asarray(rng.randint(0, P, (B, n)), jnp.int32)
    # ragged validity: tail of each row's virtual sequence masked, as the
    # paged serving cache does for empty slots
    bias = np.zeros((B, n * page), np.float32)
    for i, L in enumerate(np.linspace(page, n * page, B).astype(int)):
        bias[i, L:] = -1e30
    out = dops.paged_decode_attention(q, kp, vp, pt, jnp.asarray(bias))
    ref = dref.paged_decode_attention_ref(q, kp, vp, pt, jnp.asarray(bias))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=2e-5)


@pytest.mark.parametrize("B,C,H,K,hd,P,page,n",
                         [(2, 4, 4, 2, 64, 9, 16, 3),
                          (1, 3, 8, 8, 32, 5, 8, 4),
                          (3, 2, 4, 1, 128, 12, 32, 2)])
def test_paged_verify_attention_matches_ref(B, C, H, K, hd, P, page, n):
    """Multi-query (speculative verify) paged kernel == dense oracle,
    with per-query ragged validity (the causal-within-chunk + empty-slot
    bias the serving path feeds it)."""
    from repro.kernels.decode_attention import ops as dops
    from repro.kernels.decode_attention import ref as dref
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, C, H, hd), jnp.float32)
    kp = jnp.asarray(rng.randn(P, page, K, hd), jnp.float32)
    vp = jnp.asarray(rng.randn(P, page, K, hd), jnp.float32)
    pt = jnp.asarray(rng.randint(0, P, (B, n)), jnp.int32)
    bias = np.zeros((B, C, n * page), np.float32)
    for b in range(B):
        for c in range(C):
            bias[b, c, rng.randint(page, n * page + 1):] = -1e30
    out = dops.paged_verify_attention(q, kp, vp, pt, jnp.asarray(bias))
    ref = dref.paged_verify_attention_ref(q, kp, vp, pt, jnp.asarray(bias))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=2e-5)


def test_paged_verify_single_token_matches_decode_kernel():
    """A one-token verify chunk is exactly the single-query paged decode
    kernel — the C axis degenerates cleanly."""
    from repro.kernels.decode_attention import ops as dops
    B, H, K, hd, P, page, n = 2, 4, 2, 64, 7, 16, 3
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(B, 1, H, hd), jnp.float32)
    kp = jnp.asarray(rng.randn(P, page, K, hd), jnp.float32)
    vp = jnp.asarray(rng.randn(P, page, K, hd), jnp.float32)
    pt = jnp.asarray(rng.randint(0, P, (B, n)), jnp.int32)
    bias = np.zeros((B, 1, n * page), np.float32)
    bias[:, :, -page:] = -1e30
    out_v = dops.paged_verify_attention(q, kp, vp, pt, jnp.asarray(bias))
    out_d = dops.paged_decode_attention(q[:, 0], kp, vp, pt,
                                        jnp.asarray(bias[:, 0]))
    np.testing.assert_allclose(np.asarray(out_v[:, 0]), np.asarray(out_d),
                               rtol=1e-6, atol=1e-6)


def test_paged_decode_attention_matches_contiguous():
    """A page table that lays pages out contiguously reproduces the
    contiguous flash-decode kernel on the same cache bytes."""
    from repro.kernels.decode_attention import ops as dops
    B, H, K, hd, page, n = 2, 4, 2, 64, 16, 4
    W = n * page
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(B, H, hd), jnp.float32)
    k = jnp.asarray(rng.randn(B, W, K, hd), jnp.float32)
    v = jnp.asarray(rng.randn(B, W, K, hd), jnp.float32)
    bias = np.zeros((B, W), np.float32)
    bias[:, -page:] = -1e30
    bias = jnp.asarray(bias)
    # pool rows b*n + i hold row b's i-th page
    kp = k.reshape(B * n, page, K, hd)
    vp = v.reshape(B * n, page, K, hd)
    pt = jnp.arange(B * n, dtype=jnp.int32).reshape(B, n)
    out_p = dops.paged_decode_attention(q, kp, vp, pt, bias)
    out_c = dops.decode_attention(q, k, v, bias, block_k=page)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_c),
                               rtol=1e-5, atol=1e-5)
