"""AveryEngine front door: intent gating per session, policy/transport
plug-point swaps, in-flight batching (a request submitted mid-decode
joins the running batch), and the deprecation shims for the pre-engine
entry points."""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import packets as pk, paper_lut
from repro.core.intent import DEFAULT_REQUIREMENTS, Intent
from repro.engine import (AdaptivePolicy, AveryEngine, BestEffortPolicy,
                          ChannelTransport, LoopbackTransport,
                          StaticTierPolicy, policy_from_mode)
from repro.network import constant_trace

LUT = paper_lut()
# feasibility landmarks (paper §3.3): High Accuracy needs 11.68 Mbps at
# 0.5 PPS; the lightest tier needs 3.32 Mbps
HA_MBPS = 11.68


class StubExecutor:
    """Host-only executor: deterministic arithmetic instead of the model,
    so engine-logic tests need no XLA compiles."""
    buckets = (1, 2, 4)
    max_new_tokens = 2
    num_compiled_stages = 0

    def __init__(self, lut=LUT):
        self.lut = lut

    @staticmethod
    def _feat(images):
        return np.asarray(images, np.float64).reshape(1, -1)[:, :4]

    def edge_context(self, images, seq_id, now):
        ctx = self._feat(images)
        return pk.make_context_packet(seq_id, now, ctx), ctx

    def edge_insight(self, images, tier, seq_id, now, ctx=None):
        f = self._feat(images)
        return pk.make_insight_packet(
            seq_id, now, tier.name, codes=f.astype(np.int8),
            scales=np.ones((1, 1), np.float16), clip_feats=f)

    def cloud_context_batch(self, packets, queries):
        return [np.asarray(p.content["ctx"]).sum(axis=-1, keepdims=True)
                + np.asarray(q).sum() for p, q in zip(packets, queries)]

    def cloud_insight_batch(self, packets, queries):
        out = []
        for p, q in zip(packets, queries):
            logits = (np.asarray(p.content["clip"]).sum(axis=-1,
                                                        keepdims=True)
                      + np.asarray(q).sum())
            out.append((np.tile(logits[:, None], (1, 2, 2)), logits))
        return out


def _insight_images(rng):
    return rng.rand(1, 4, 4, 3)


# ---- intent gating + per-session context ----


def test_intent_gating_per_session():
    engine = AveryEngine(lut=LUT, executor=StubExecutor())
    sess = engine.session("op0")
    rng = np.random.RandomState(0)
    q = np.zeros((1, 4), np.int32)
    f_ctx = sess.submit(prompt="is there anyone in the sector?",
                        images=_insight_images(rng), query=q)
    f_ins = sess.submit(prompt="segment the stranded person",
                        images=_insight_images(rng), query=q)
    engine.drain()
    assert f_ctx.result().intent is Intent.CONTEXT
    assert f_ctx.result().tier_name is None
    assert f_ins.result().intent is Intent.INSIGHT
    assert f_ins.result().tier_name in {t.name for t in LUT.tiers}
    assert [h[2] for h in sess.history] == [Intent.CONTEXT, Intent.INSIGHT]


# ---- ControlPolicy swap ----


def _submit_one(policy, bandwidth_mbps):
    engine = AveryEngine(lut=LUT, executor=StubExecutor(),
                         transport=LoopbackTransport(bandwidth_mbps),
                         policy=policy)
    fut = engine.session("op").submit(
        prompt="segment the person",
        images=_insight_images(np.random.RandomState(0)),
        query=np.zeros((1, 4), np.int32))
    engine.drain()
    return fut.result()


def test_policy_swap_changes_tier_selection():
    """§5.3 adaptive-vs-static is a one-line policy swap."""
    adaptive = _submit_one(AdaptivePolicy(), bandwidth_mbps=9.0)
    static = _submit_one(StaticTierPolicy("High Accuracy"),
                         bandwidth_mbps=9.0)
    assert adaptive.tier_name == "Balanced"    # HA infeasible below 11.68
    assert static.tier_name == "High Accuracy"


def test_best_effort_policy_degrades_instead_of_idling():
    strict = _submit_one(AdaptivePolicy(), bandwidth_mbps=1.0)
    assert not strict.feasible and strict.tier_name is None
    assert strict.answer_logits is None
    served = _submit_one(BestEffortPolicy(), bandwidth_mbps=1.0)
    assert not served.feasible
    assert served.tier_name == "High Throughput"   # lightest tier
    assert served.answer_logits is not None


@settings(max_examples=20, deadline=None)
@given(bw_lo=st.floats(min_value=3.4, max_value=25.0),
       bw_hi=st.floats(min_value=3.4, max_value=25.0))
def test_adaptive_policy_accuracy_monotone_in_bandwidth(bw_lo, bw_hi):
    """More bandwidth never selects a less accurate tier (accuracy goal)."""
    if bw_lo > bw_hi:
        bw_lo, bw_hi = bw_hi, bw_lo
    pol = AdaptivePolicy()
    reqs = DEFAULT_REQUIREMENTS[Intent.INSIGHT]
    lo = pol.select(bw_lo, Intent.INSIGHT, reqs, LUT)
    hi = pol.select(bw_hi, Intent.INSIGHT, reqs, LUT)
    assert lo.tier is not None and hi.tier is not None
    assert hi.tier.acc_base >= lo.tier.acc_base


# ---- Transport swap ----


def test_transport_swap_preserves_results():
    rng = np.random.RandomState(3)
    frames = [_insight_images(rng) for _ in range(4)]
    results = {}
    for name, transport in (
            ("loopback", LoopbackTransport(12.0)),
            ("channel", ChannelTransport.from_trace(constant_trace(12.0,
                                                                   600)))):
        engine = AveryEngine(lut=LUT, executor=StubExecutor(),
                             transport=transport)
        sess = engine.session("op")
        futs = [sess.submit(prompt="segment the person", images=f,
                            query=np.zeros((1, 4), np.int32),
                            time_s=float(i))
                for i, f in enumerate(frames)]
        engine.drain()
        results[name] = [f.result() for f in futs]
    for lo, ch in zip(results["loopback"], results["channel"]):
        np.testing.assert_allclose(lo.answer_logits, ch.answer_logits)
        np.testing.assert_allclose(lo.mask_logits, ch.mask_logits)
        assert lo.tier_name == ch.tier_name
    # the simulated channel actually serialises packets; loopback doesn't
    assert all(r.latency_s == 0.0 for r in results["loopback"])
    assert all(r.latency_s > 0.0 for r in results["channel"])


def test_drain_returns_each_response_once():
    """A submit/drain/submit stream neither re-returns history nor
    accumulates served futures in the engine tables."""
    engine = AveryEngine(lut=LUT, executor=StubExecutor())
    sess = engine.session("op")
    rng = np.random.RandomState(5)
    q = np.zeros((1, 4), np.int32)
    f1 = sess.submit(prompt="segment the person",
                     images=_insight_images(rng), query=q)
    first = engine.drain()
    assert [r.request_id for r in first] == [f1.request.request_id]
    f2 = sess.submit(prompt="segment the vehicle",
                     images=_insight_images(rng), query=q)
    second = engine.drain()
    assert [r.request_id for r in second] == [f2.request.request_id]
    assert f1.result() is first[0]      # the future keeps its response
    assert engine.drain() == []
    assert not engine._futures          # served requests were evicted


def test_profiled_context_frame_has_no_tier():
    """submit_frame handles the Context stream: CLIP-only edge cost, the
    fixed lightweight payload, no tier, always feasible."""
    engine = AveryEngine(lut=LUT)          # profiled: no executor needed
    sess = engine.session("op")
    ins = sess.submit_frame(0.0)
    ctx = sess.submit_frame(1.0, intent=Intent.CONTEXT)
    assert ctx.feasible and ctx.tier_name is None
    assert ctx.intent is Intent.CONTEXT
    assert 0.0 < ctx.edge_energy_j < ins.edge_energy_j
    assert ctx.t_delivered >= 1.0


def test_session_classify_hook_routes_intent():
    """submit() goes through session.classify, so per-session gating is
    an override point."""
    class PinnedSession(type(AveryEngine(lut=LUT).session("tmp"))):
        def classify(self, prompt):
            return Intent.INSIGHT

    engine = AveryEngine(lut=LUT, executor=StubExecutor())
    sess = PinnedSession(engine=engine, operator_id="pinned")
    fut = sess.submit(prompt="is there anyone?",   # would gate CONTEXT
                      images=_insight_images(np.random.RandomState(0)),
                      query=np.zeros((1, 4), np.int32))
    engine.drain()
    assert fut.result().intent is Intent.INSIGHT


def test_inflight_stats_safe_with_no_requests():
    engine = AveryEngine(lut=LUT, executor=StubExecutor(),
                         batching="inflight")
    assert engine.stats["inflight_steps"] == 0
    assert engine.stats["mean_live_slots"] == 0.0


# ---- deprecation shims ----


def test_mode_string_shim_maps_to_policies():
    assert isinstance(policy_from_mode("avery"), AdaptivePolicy)
    assert isinstance(policy_from_mode("avery", fallback=True),
                      BestEffortPolicy)
    static = policy_from_mode("static", "Balanced")
    assert isinstance(static, StaticTierPolicy)
    assert static.tier_name == "Balanced"
    with pytest.raises(ValueError):
        policy_from_mode("static")
    with pytest.raises(ValueError):
        policy_from_mode("greedy")


def test_mission_mode_strings_match_policy_objects():
    """The pre-engine MissionSpec knobs drive the same engine pipeline."""
    from repro.runtime import MissionSpec, run_mission
    trace = constant_trace(12.0, 120)
    by_mode = run_mission(LUT, trace, MissionSpec(duration_s=120.0,
                                                  mode="avery"))
    by_policy = run_mission(LUT, trace, MissionSpec(
        duration_s=120.0, policy=AdaptivePolicy()))
    assert [f.tier for f in by_mode.frames] == \
        [f.tier for f in by_policy.frames]
    assert by_mode.mean_iou == by_policy.mean_iou
    spec = MissionSpec(mode="static", static_tier="Balanced")
    assert isinstance(spec.resolve_policy(), StaticTierPolicy)


def test_runtime_reexports_still_importable():
    """Pre-engine import sites keep working."""
    from repro.runtime import (MicrobatchScheduler, ServeRequest,  # noqa: F401
                               edge_insight_flops, full_edge_flops)
    from repro.runtime.mission import FidelityOracle  # noqa: F401
    from repro.launch.serve import serve_local
    import inspect
    assert "smoke" in inspect.signature(serve_local).parameters


# ---- real-model integration: serve path + in-flight batching ----


@pytest.fixture(scope="module")
def executor():
    from repro.configs.lisa_mini import CONFIG as PCFG
    from repro.core import DualStreamExecutor, profile as prof
    params, bns, _ = prof.random_init_system(PCFG, lut=LUT)
    return DualStreamExecutor(pcfg=PCFG, params=params, bottlenecks=bns,
                              lut=LUT, max_new_tokens=3, flash_decode=False)


def _edge_requests(executor, n, seed=0):
    import jax.numpy as jnp

    from repro.data import floodseg
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n):
        kind = "any" if i % 3 == 2 else "segment"
        b = floodseg.make_batch(rng, 1, kind, augment=False)
        img = jnp.asarray(b["images"])
        if kind == "any":
            pkt, _ = executor.edge_context(img, i, 0.0)
            out.append((pkt, b["query"], Intent.CONTEXT))
        else:
            pkt = executor.edge_insight(img, LUT.tiers[i % 2], i, 0.0)
            out.append((pkt, b["query"], Intent.INSIGHT))
    return out


def test_engine_serve_path_matches_executor(executor):
    """Microbatched engine responses equal direct executor calls."""
    reqs = _edge_requests(executor, 5, seed=11)
    engine = AveryEngine(lut=LUT, executor=executor, max_batch=4)
    futs = [engine.submit_packet(p, q, it, time_s=float(i))
            for i, (p, q, it) in enumerate(reqs)]
    engine.drain()
    for fut, (pkt, q, it) in zip(futs, reqs):
        res = fut.result()
        if it is Intent.INSIGHT:
            mask, logits = executor.cloud_insight(pkt, q)
            np.testing.assert_allclose(res.mask_logits, mask, atol=3e-4)
        else:
            logits = executor.cloud_context(pkt, q)
        np.testing.assert_allclose(res.answer_logits, logits, atol=3e-4)
    assert engine.stats["n_microbatches"] < len(reqs)


def test_submitted_request_joins_inflight_batch(executor):
    """In-flight batching: a request submitted while a decode batch is
    running is prefilled into a free slot and served by that batch —
    and its results match the one-shot generate path exactly."""
    reqs = _edge_requests(executor, 2, seed=21)
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=4)
    (p1, q1, i1), (p2, q2, i2) = reqs
    f1 = engine.submit_packet(p1, q1, i1, time_s=0.0)
    engine.pump()                      # the decode batch is now running
    f2 = engine.submit_packet(p2, q2, i2, time_s=0.1)
    engine.drain()
    r1, r2 = f1.result(), f2.result()
    assert r2.joined_step is not None and r2.joined_step > 0
    assert r1.batch_size > 1.0 or r2.batch_size > 1.0  # steps were shared
    for res, (pkt, q, it) in zip((r1, r2), reqs):
        out = executor.cloud_generate_batch([pkt], [q])[0]
        if it is Intent.INSIGHT:
            mask, logits0, toks = out
            np.testing.assert_allclose(res.mask_logits, mask, atol=3e-4)
        else:
            logits0, toks = out
        np.testing.assert_allclose(res.answer_logits, logits0, atol=3e-4)
        assert np.array_equal(res.tokens, toks)


@pytest.mark.slow
def test_inflight_matches_one_shot_across_tiers_and_intents(executor):
    """Staggered joins across mixed tiers AND intents in one running
    batch still reproduce per-request one-shot generate results."""
    reqs = _edge_requests(executor, 6, seed=31)
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=3)
    futs = [engine.submit_packet(p, q, it, time_s=float(i))
            for i, (p, q, it) in enumerate(reqs)]
    engine.drain()
    joined = []
    for fut, (pkt, q, it) in zip(futs, reqs):
        res = fut.result()
        joined.append(res.joined_step)
        out = executor.cloud_generate_batch([pkt], [q])[0]
        if it is Intent.INSIGHT:
            mask, logits0, toks = out
            np.testing.assert_allclose(res.mask_logits, mask, atol=3e-4)
        else:
            logits0, toks = out
        np.testing.assert_allclose(res.answer_logits, logits0, atol=3e-4)
        assert np.array_equal(res.tokens, toks)
    assert max(joined) > 0             # later requests joined mid-stream
    assert engine.stats["mean_live_slots"] > 1.0


# ---- paged KV cache: slot reuse, prefix sharing, admission pump ----


def test_slot_reuse_parity_with_one_shot_generate(executor):
    """More requests than slots through one decoder (forcing slot and
    page reuse) still reproduce per-request one-shot generate results —
    a reused slot must never attend a leftover token (the contiguous
    cache's stale-ring-slot hazard, structural in the paged layout:
    freed rows park on the trash page and positions reset)."""
    reqs = _edge_requests(executor, 5, seed=41)
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=2)
    futs = [engine.submit_packet(p, q, it, time_s=float(i))
            for i, (p, q, it) in enumerate(reqs)]
    engine.drain()
    for fut, (pkt, q, it) in zip(futs, reqs):
        res = fut.result()
        out = executor.cloud_generate_batch([pkt], [q])[0]
        if it is Intent.INSIGHT:
            mask, logits0, toks = out
            np.testing.assert_allclose(res.mask_logits, mask, atol=3e-4)
        else:
            logits0, toks = out
        np.testing.assert_allclose(res.answer_logits, logits0, atol=3e-4)
        assert np.array_equal(res.tokens, toks)
    # all private pages returned; only cached prefix pages stay pinned
    from repro.core.paging import pages_for
    stats = engine.stats
    qlen = reqs[0][1].shape[-1]
    per_prefix = pages_for(executor.pcfg.clip_tokens + qlen,
                           executor.page_size)
    assert stats["kv_pages_in_use"] == stats["prefix_entries"] * per_prefix


def test_prefix_reuse_and_release(executor):
    """Repeat-prefix frames from one operator hit the prefix store (one
    prefill for M frames), hits serve byte-identical results, and
    draining with ``release_operator`` frees the cached pages."""
    import jax.numpy as jnp

    from repro.data import floodseg
    rng = np.random.RandomState(51)
    b = floodseg.make_batch(rng, 1, "segment", augment=False)
    img = jnp.asarray(b["images"])
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=4)
    sessA = engine.session("uav-A")
    sessB = engine.session("uav-B")
    futs = []
    for i in range(3):           # same frame + standing query -> same prefix
        pkt = executor.edge_insight(img, LUT.tiers[0], i, 0.0)
        futs.append(engine.submit_packet(pkt, b["query"], Intent.INSIGHT,
                                         time_s=float(i), session=sessA))
    # same content under another operator must NOT share (per-operator key)
    pkt = executor.edge_insight(img, LUT.tiers[0], 3, 0.0)
    fut_b = engine.submit_packet(pkt, b["query"], Intent.INSIGHT,
                                 time_s=3.0, session=sessB)
    engine.drain()
    hits = [f.result().prefix_hit for f in futs]
    assert hits == [False, True, True]
    assert fut_b.result().prefix_hit is False
    stats = engine.stats
    assert stats["prefix_hits"] == 2 and stats["prefix_misses"] == 2
    assert 0.0 < stats["prefix_hit_rate"] < 1.0
    assert stats["prefix_entries"] == 2
    assert stats["kv_pages_in_use"] > 0
    # hit responses equal the miss response byte-for-byte
    r0 = futs[0].result()
    for f in futs[1:]:
        np.testing.assert_array_equal(f.result().answer_logits,
                                      r0.answer_logits)
        np.testing.assert_array_equal(f.result().tokens, r0.tokens)
    # releasing one operator frees exactly their entry; close() the other
    assert engine.release_prefixes("uav-A") == 1
    assert engine.stats["prefix_entries"] == 1
    assert sessB.close() == 1
    assert engine.stats["kv_pages_in_use"] == 0


def test_pump_admits_pending_when_no_batch_is_running(executor):
    """``pump`` must start pending requests even when ``active`` is empty
    (the engine's lazy-drive paths reach the decoder in that state);
    before the fix it returned without admitting and the request hung."""
    from repro.engine.inflight import InflightDecoder, _PendingRequest
    reqs = _edge_requests(executor, 1, seed=61)
    pkt, q, it = reqs[0]
    dec = InflightDecoder(executor, slots=2)
    done = []
    dec.qlen = int(np.asarray(q).shape[-1])
    dec.pending.append(_PendingRequest(0, it, pkt, np.asarray(q),
                                       done.append))
    assert not dec.active
    for _ in range(executor.max_new_tokens):
        dec.pump(1)
    assert len(done) == 1
    out = executor.cloud_generate_batch([pkt], [q])[0]
    assert np.array_equal(done[0]["tokens"], out[-1])


def test_blackout_resolves_request_as_failed():
    """A transport blackout (all-zero trace) surfaces as a failed,
    infeasible-style response the policy can react to — not a hang."""
    from repro.network import Channel
    from repro.network.traces import BandwidthTrace
    trace = BandwidthTrace(np.zeros(10), name="dead")
    engine = AveryEngine(lut=LUT, executor=StubExecutor(),
                         transport=ChannelTransport(Channel(trace)),
                         policy=StaticTierPolicy("High Throughput"))
    fut = engine.session("op").submit(
        prompt="segment the person",
        images=_insight_images(np.random.RandomState(0)),
        query=np.zeros((1, 4), np.int32))
    out = engine.drain()
    res = fut.result()
    assert res is out[0]
    assert not res.feasible and res.answer_logits is None
    assert any(e.kind == "blackout" for e in res.events)
    assert engine.stats["blackouts"] == 1


def test_engine_speculative_knob_matches_generate(executor):
    """``speculative=True`` serves through Context-stream drafts + paged
    multi-token verify; results stay equal to the one-shot generate
    path and the engine reports acceptance/tokens-per-step stats."""
    reqs = _edge_requests(executor, 4, seed=81)
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=4, speculative=True)
    futs = [engine.submit_packet(p, q, it, time_s=float(i))
            for i, (p, q, it) in enumerate(reqs)]
    engine.drain()
    for fut, (pkt, q, it) in zip(futs, reqs):
        res = fut.result()
        assert res.speculative is True
        out = executor.cloud_generate_batch([pkt], [q])[0]
        if it is Intent.INSIGHT:
            mask, logits0, toks = out
            np.testing.assert_allclose(res.mask_logits, mask, atol=3e-4)
        else:
            logits0, toks = out
        np.testing.assert_allclose(res.answer_logits, logits0, atol=3e-4)
        assert np.array_equal(res.tokens, toks)
    stats = engine.stats
    # the warm Context weights draft for themselves: full acceptance
    assert stats["spec_acceptance_rate"] == 1.0
    assert stats["spec_tokens_per_step"] >= 1.5
    assert stats["spec_disabled_steps"] == 0
    assert stats["kv_pages_peak"] >= stats["kv_pages_in_use"]


def test_policy_floor_disables_drafting(executor):
    """The acceptance-rate floor is a ControlPolicy lever: a divergent
    draft model trips ``AdaptivePolicy.allow_speculation`` after the
    warm-up samples and the engine falls back to plain decode — output
    still exact."""
    import jax

    from repro.configs.lisa_mini import CONFIG as PCFG
    from repro.core import vlm
    from repro.engine import SpeculativeConfig
    spec = SpeculativeConfig(
        draft_tokens=2, acceptance_floor=0.5, min_draft_samples=4,
        draft_params=vlm.init_lisa(PCFG, jax.random.PRNGKey(123)))
    reqs = _edge_requests(executor, 4, seed=91)
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=2, speculative=spec)
    futs = [engine.submit_packet(p, q, it, time_s=float(i))
            for i, (p, q, it) in enumerate(reqs)]
    engine.drain()
    for fut, (pkt, q, it) in zip(futs, reqs):
        out = executor.cloud_generate_batch([pkt], [q])[0]
        assert np.array_equal(fut.result().tokens, out[-1])
    stats = engine.stats
    assert stats["spec_acceptance_rate"] < 0.5
    assert stats["spec_disabled_steps"] > 0
    # the gate decides on engine-lifetime stats: a later burst (fresh
    # decoder after drain) must stay disabled, not re-pay the warm-up
    pkt, q, it = reqs[0]
    fut = engine.submit_packet(pkt, q, it, time_s=10.0)
    engine.drain()
    out = executor.cloud_generate_batch([pkt], [q])[0]
    assert np.array_equal(fut.result().tokens, out[-1])
    assert engine.stats["spec_drafted"] == stats["spec_drafted"]
    # a static policy never adapts: same draft, drafting stays on
    engine2 = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                          max_batch=2, speculative=spec,
                          policy=StaticTierPolicy("Balanced"))
    for i, (p, q, it) in enumerate(reqs):
        engine2.submit_packet(p, q, it, time_s=float(i))
    engine2.drain()
    assert engine2.stats["spec_disabled_steps"] == 0
    assert engine2.stats["spec_drafted"] > stats["spec_drafted"]


def test_speculative_requires_inflight_batching():
    with pytest.raises(ValueError):
        AveryEngine(lut=LUT, executor=StubExecutor(), speculative=True)


def test_engine_max_prefixes_caps_store(executor):
    """The engine's ``max_prefixes`` knob LRU-caps the prefix store
    across operators without disturbing live serving."""
    import jax.numpy as jnp

    from repro.data import floodseg
    rng = np.random.RandomState(101)
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=2, max_prefixes=2)
    for i in range(4):                    # 4 distinct operators/prefixes
        b = floodseg.make_batch(rng, 1, "segment", augment=False)
        pkt = executor.edge_insight(jnp.asarray(b["images"]), LUT.tiers[0],
                                    i, 0.0)
        engine.submit_packet(pkt, b["query"], Intent.INSIGHT,
                             time_s=float(i),
                             session=engine.session(f"uav-{i}"))
    engine.drain()
    stats = engine.stats
    assert stats["prefix_entries"] <= 2
    assert stats["prefix_evictions"] >= 2


def test_no_share_prefixes_frees_all_pages(executor):
    """With the prefix store disabled every request owns its prefix
    pages outright — they must free when the request finishes (no
    refcount leak), leaving the pool empty after a drain."""
    reqs = _edge_requests(executor, 3, seed=71)
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=2, share_prefixes=False)
    futs = [engine.submit_packet(p, q, it, time_s=float(i))
            for i, (p, q, it) in enumerate(reqs)]
    engine.drain()
    assert all(f.result().prefix_hit is False for f in futs)
    stats = engine.stats
    assert stats["prefix_entries"] == 0
    assert stats["kv_pages_in_use"] == 0    # everything returned


# ---- lisa_nano draft + sharded serving knobs ----


def test_engine_nano_draft_speculative_matches_generate(executor):
    """``speculative="nano"``: the truly-small lisa_nano draft (the
    target's truncated trunk, sliced not trained) serves token-exact
    through the engine — acceptance only moves the cost, never the
    output — and the draft really is 1 layer of the target's 4."""
    import jax

    from repro.configs import lisa_nano

    reqs = _edge_requests(executor, 3, seed=31)
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=2, speculative="nano")
    assert engine.spec_config.draft_pcfg.llm.num_layers \
        == lisa_nano.DRAFT_LAYERS
    leaf = jax.tree.leaves(engine.spec_config.draft_params["llm"]
                           ["groups"][0])[0]
    assert leaf.shape[0] == lisa_nano.DRAFT_LAYERS
    futs = [engine.submit_packet(p, q, it, time_s=0.0)
            for (p, q, it) in reqs]
    engine.drain()
    for fut, (pkt, q, it) in zip(futs, reqs):
        ref = executor.cloud_generate_batch([pkt], [q])[0]
        assert np.array_equal(fut.result().tokens, ref[-1])
    assert engine.stats["spec_drafted"] > 0


def test_engine_mesh_knob_shards_serving(executor):
    """``AveryEngine(mesh=...)`` wraps the executor in a
    ShardedServingContext, keeps the PagePool mesh-resident, reports
    the mesh telemetry, and serves token-exact vs the one-shot path
    (degenerate 1-shard mesh on this host; the multi-shard pin lives in
    test_sharding's 1x2 subprocess test)."""
    from repro.launch.mesh import make_local_mesh
    from repro.sharding.serving import ShardedServingContext

    # only the paged in-flight stages run sharded: a microbatch engine
    # would silently serve unsharded while reporting mesh telemetry
    with pytest.raises(ValueError):
        AveryEngine(lut=LUT, executor=executor, mesh=make_local_mesh())
    reqs = _edge_requests(executor, 2, seed=41)
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=2, mesh=make_local_mesh(model=1))
    assert isinstance(engine.executor, ShardedServingContext)
    assert engine.kv_pool.placement is not None
    futs = [engine.submit_packet(p, q, it, time_s=0.0)
            for (p, q, it) in reqs]
    engine.drain()
    for fut, (pkt, q, it) in zip(futs, reqs):
        ref = executor.cloud_generate_batch([pkt], [q])[0]
        assert np.array_equal(fut.result().tokens, ref[-1])
    stats = engine.stats
    assert stats["mesh_devices"] >= 1
    assert stats["model_shards"] >= 1
    assert stats["kv_pool_bytes_per_shard"] > 0


# ---- QoS scheduling through the engine front door ----


def test_preempted_request_resumes_token_exact(executor):
    """Page-rollback preemption round trip on a 1-slot decoder: an
    urgent Context request evicts the running Insight decode; the victim
    parks (private pages rolled back to the prefix), resumes, replays
    its generated-so-far tokens, and still finishes with exactly the
    tokens of the uncontended one-shot generate path."""
    from repro.engine import QoSScheduler
    reqs = _edge_requests(executor, 3, seed=61)
    bulk, _, urgent = reqs               # i%3==2 is the CONTEXT request
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=1, debug_invariants=True,
                         scheduler=QoSScheduler(latency_patience_s=0.0))
    f_a = engine.submit_packet(*bulk, time_s=0.0)
    f_c = engine.submit_packet(*urgent, time_s=1.0)
    engine.drain()
    r_a = f_a.result()
    assert r_a.preemptions == 1
    for fut, (pkt, q, _) in ((f_a, bulk), (f_c, urgent)):
        ref = executor.cloud_generate_batch([pkt], [q])[0]
        assert np.array_equal(fut.result().tokens, ref[-1])
    st = engine.stats
    assert st["sched_preemptions"] == 1
    assert st["sched_resumed_served"] == 1
    assert st["sched_tokens_replayed"] >= 1
    engine.kv_pool.check_invariants()


def test_rate_limited_operator_shed_before_edge_compute():
    """An operator over its token bucket is rejected at the front door:
    the future resolves ``failure="rejected"`` with zero transmissions
    for the shed requests, and the telemetry attributes the reason."""
    from repro.engine import QoSScheduler
    engine = AveryEngine(lut=LUT, executor=StubExecutor(),
                         scheduler=QoSScheduler(rate_per_s=1.0, burst=1.0))
    sess = engine.session("spammy")
    rng = np.random.RandomState(0)
    futs = [sess.submit(prompt="segment the person",
                        images=_insight_images(rng),
                        query=np.zeros((1, 4), np.int32), time_s=0.0)
            for _ in range(3)]
    engine.drain()
    fails = [f.result().failure for f in futs]
    assert fails == [None, "rejected", "rejected"]
    assert all(any(e.kind == "rejected" for e in f.result().events)
               for f in futs[1:])
    st = engine.stats
    assert st["rejected"] == 2
    assert st["sched_rejected_rate_limit"] == 2
    assert engine.transport.n_sent == 1  # shed before any transmission


def test_bounded_queue_sheds_queue_full(executor):
    """A full per-class pending queue sheds at enqueue (after transport,
    before any prefill); everything that was admitted still serves
    token-exact."""
    from repro.engine import QoSScheduler
    reqs = [r for r in _edge_requests(executor, 5, seed=71)
            if r[2] is Intent.INSIGHT]   # 4 same-class requests
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=1, debug_invariants=True,
                         scheduler=QoSScheduler(max_queue=1))
    futs = [engine.submit_packet(p, q, it, time_s=float(i))
            for i, (p, q, it) in enumerate(reqs)]
    engine.drain()
    results = [f.result() for f in futs]
    shed = [r for r in results if r.failure == "rejected"]
    assert shed and engine.stats["sched_rejected_queue_full"] == len(shed)
    for res, (pkt, q, _) in zip(results, reqs):
        if res.failure is None:
            ref = executor.cloud_generate_batch([pkt], [q])[0]
            assert np.array_equal(res.tokens, ref[-1])
    engine.kv_pool.check_invariants()


def test_expired_pending_request_never_pays_prefill(executor):
    """The admission-boundary deadline sweep: a request whose SLO
    expired while queued resolves ``failure="deadline"`` without ever
    calling the prefill — dead requests cost no cloud compute."""

    class CountingExecutor:
        def __init__(self, inner):
            self._inner = inner
            self.prefix_calls = 0

        def __getattr__(self, name):
            return getattr(self._inner, name)

        def cloud_prefix(self, ctx, query):
            self.prefix_calls += 1
            return self._inner.cloud_prefix(ctx, query)

    counting = CountingExecutor(executor)
    engine = AveryEngine(lut=LUT, executor=counting, batching="inflight",
                         max_batch=1, debug_invariants=True)
    plain = engine.session("plain")
    slo = engine.session("slo", requirements={
        Intent.CONTEXT: DEFAULT_REQUIREMENTS[Intent.CONTEXT],
        Intent.INSIGHT: dataclasses.replace(
            DEFAULT_REQUIREMENTS[Intent.INSIGHT], max_latency_s=0.5)})
    (pa, qa, ia), (pb, qb, ib), _, (pc, qc, ic), _ = \
        _edge_requests(executor, 5, seed=81)
    f_a = engine.submit_packet(pa, qa, ia, time_s=0.0, session=plain)
    f_b = engine.submit_packet(pb, qb, ib, time_s=0.1, session=slo)
    f_c = engine.submit_packet(pc, qc, ic, time_s=5.0, session=plain)
    engine.drain()
    assert f_b.result().failure == "deadline"
    assert f_a.result().failure is None and f_c.result().failure is None
    assert counting.prefix_calls == 2    # A and C only; B never prefilled
    assert engine.stats["sched_expired_pending"] == 1
    engine.kv_pool.check_invariants()
