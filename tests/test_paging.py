"""PagePool allocator + prefix-store invariants: LRU eviction under
``max_prefixes`` (refcount-safe against live sharers), the
``kv_pages_peak`` high-water mark that sizes pools for speculative
bursts, speculative grow/rollback, and a property test that random
alloc/retain/release/put_prefix/release_operator/park/resume
interleavings never leak or double-free pages."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.paging import TRASH_PAGE, PagePool, pages_for


def _pool(**kw) -> PagePool:
    """A pool with device storage stubbed in (host bookkeeping only):
    seed the allocator via ``ensure`` on a tiny kv-shaped pytree."""
    import jax.numpy as jnp
    pool = PagePool(page_size=kw.pop("page_size", 4), **kw)
    like = {"groups": [{"k": jnp.zeros((1, 1, pool.page_size, 1, 1)),
                        "v": jnp.zeros((1, 1, pool.page_size, 1, 1))}]}
    pool.ensure(8, like=like)
    return pool


def _invariant(pool: PagePool) -> None:
    """Conservation: every page is exactly one of {trash, live, free} —
    the pool's own audit plus the historical spot checks."""
    summary = pool.check_invariants()
    assert summary["pages_in_use"] + summary["pages_free"] \
        == summary["pages_total"] - 1
    assert sorted(set(pool._free)) == sorted(pool._free)   # no dup frees
    assert TRASH_PAGE not in pool._free
    for i in pool._free:
        assert pool._refcount[i] == 0


def test_check_invariants_catches_corruption():
    """The audit actually fires: a duplicated free-list id, a freed page
    still referenced by a prefix entry, and a negative refcount each
    raise; an unseeded (storage-less) pool audits clean."""
    PagePool(page_size=4).check_invariants()       # empty pool: no-op
    pool = _pool()
    ids = pool.alloc(2)
    pool.release(ids)
    pool._free.append(ids[0])                      # duplicate free
    with pytest.raises(RuntimeError, match="duplicate"):
        pool.check_invariants()
    pool = _pool()
    ids = pool.alloc(1)
    pool.put_prefix(("op", "x"), ids, 4, np.zeros((1, 4)))
    pool.release(ids)
    pool._refcount[ids[0]] = 0                     # store pin lost
    with pytest.raises(RuntimeError):
        pool.check_invariants()
    pool = _pool()
    ids = pool.alloc(1)
    pool.release(ids)
    pool._refcount[ids[0]] = -1                    # double release
    with pytest.raises(RuntimeError, match="negative"):
        pool.check_invariants()


# ---- LRU eviction (max_prefixes cap) ----


def test_lru_eviction_order_and_refresh():
    pool = _pool(max_prefixes=2)
    for name in ("a", "b"):
        ids = pool.alloc(1)
        pool.put_prefix(("op", name), ids, 4, np.zeros((1, 4)))
        pool.release(ids)            # request finishes; store pin remains
    assert pool.lookup_prefix(("op", "a")) is not None   # refresh 'a'
    ids = pool.alloc(1)
    pool.put_prefix(("op", "c"), ids, 4, np.zeros((1, 4)))
    pool.release(ids)
    # 'b' was least-recently-hit -> evicted; 'a' survived its refresh
    assert set(k[1] for k in pool.prefix) == {"a", "c"}
    assert pool.prefix_evictions == 1
    _invariant(pool)


def test_lru_eviction_of_entry_with_live_sharer_is_refcount_safe():
    """Evicting an entry whose pages a live slot still retains must only
    drop the store's pin: the pages stay allocated for the live request
    and free when it releases them."""
    pool = _pool(max_prefixes=1)
    ids_a = pool.alloc(2)
    entry_a = pool.put_prefix(("op", "a"), ids_a, 8, np.zeros((1, 4)))
    # a second request shares the prefix (one retain per sharer) and is
    # still decoding when the entry gets evicted
    pool.retain(entry_a.page_ids)
    pool.release(ids_a)              # first request finished
    ids_b = pool.alloc(1)
    pool.put_prefix(("op", "b"), ids_b, 4, np.zeros((1, 4)))  # evicts 'a'
    pool.release(ids_b)
    assert pool.prefix_evictions == 1
    assert ("op", "a") not in pool.prefix
    # the live sharer still holds the pages: not freed, not reusable
    assert all(pool._refcount[i] == 1 for i in ids_a)
    assert all(i not in pool._free for i in ids_a)
    _invariant(pool)
    pool.release(ids_a)              # the live request finishes
    assert all(i in pool._free for i in ids_a)
    _invariant(pool)


def test_max_prefixes_validation():
    with pytest.raises(ValueError):
        PagePool(max_prefixes=0)


# ---- kv_pages_peak high-water mark ----


def test_kv_pages_peak_tracks_transient_bursts():
    pool = _pool()
    a = pool.alloc(5)
    assert pool.stats()["kv_pages_peak"] == 5
    pool.release(a[2:])              # burst subsides
    assert pool.pages_in_use == 2
    assert pool.stats()["kv_pages_peak"] == 5    # peak sticks
    b = pool.alloc(2)
    assert pool.stats()["kv_pages_peak"] == 5    # below peak: unchanged
    c = pool.alloc(3)
    assert pool.stats()["kv_pages_peak"] == 7
    pool.release(a[:2]); pool.release(b); pool.release(c)
    _invariant(pool)


# ---- speculative grow / rollback ----


def test_grow_and_rollback_private_run():
    pool = _pool(page_size=4)
    run = []
    fresh = pool.grow_to(run, 3)                  # 3 tokens -> 1 page
    assert len(run) == 1 and fresh == run
    assert pool.grow_to(run, 4) == []             # still covered
    fresh = pool.grow_to(run, 11)                 # draft overhang: 3 pages
    assert len(run) == 3 and len(fresh) == 2
    peak = pool.kv_pages_peak
    dropped = pool.rollback_to(run, 5)            # accept 5 -> keep 2 pages
    assert len(run) == 2 and len(dropped) == 1
    assert all(i in pool._free for i in dropped)
    assert pool.rollback_to(run, 8) == []         # exact cover: no-op
    assert pool.kv_pages_peak == peak             # rollback keeps the peak
    pool.release(run)
    _invariant(pool)


def test_rollback_respects_shared_refcounts():
    """A page in the run that something else retains survives rollback
    (only this run's reference drops)."""
    pool = _pool(page_size=2)
    run = []
    pool.grow_to(run, 6)                          # 3 pages
    shared = run[-1]
    pool.retain([shared])
    dropped = pool.rollback_to(run, 2)
    assert shared in dropped
    assert pool._refcount[shared] == 1 and shared not in pool._free
    pool.release([shared])
    pool.release(run)
    _invariant(pool)


# ---- property test: random op interleavings conserve pages ----


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_ops=st.integers(min_value=5, max_value=60))
def test_pool_ops_never_leak_or_double_free(seed, n_ops):
    import random
    rng = random.Random(seed)
    pool = _pool(page_size=4,
                 max_prefixes=rng.choice([None, 1, 2, 3]))
    held = []                 # [(ids, kind)] request-held references
    slots = []                # [(key, prefix_ids, run)] admitted "slots"
    parked = []               # preempted requests: page-free, key only
    n_prefix = 0
    for _ in range(n_ops):
        op = rng.choice(["alloc", "release", "retain", "put_prefix",
                         "release_operator", "lookup", "grow",
                         "rollback", "admit", "cancel", "park", "resume"])
        if op == "alloc":
            held.append((pool.alloc(rng.randint(1, 3)), "plain"))
        elif op == "release" and held:
            ids, _ = held.pop(rng.randrange(len(held)))
            pool.release(ids)
        elif op == "retain" and held:
            ids, kind = held[rng.randrange(len(held))]
            pool.retain(ids)
            held.append((list(ids), kind))
        elif op == "put_prefix":
            ids = pool.alloc(rng.randint(1, 3))
            key = (f"op{rng.randint(0, 2)}", f"d{n_prefix}")
            n_prefix += 1
            pool.put_prefix(key, ids, len(ids) * pool.page_size,
                            np.zeros((1, 2)))
            held.append((ids, "prefix"))
        elif op == "release_operator":
            pool.release_operator(f"op{rng.randint(0, 2)}")
        elif op == "lookup" and pool.prefix:
            key = rng.choice(list(pool.prefix))
            entry = pool.lookup_prefix(key)
            if entry is not None and rng.random() < 0.5:
                pool.retain(entry.page_ids)      # a sharer joins...
                held.append((list(entry.page_ids), "share"))
        elif op == "grow":
            run = pool.alloc(1)
            pool.grow_to(run, rng.randint(1, 5) * pool.page_size)
            held.append((run, "run"))
        elif op == "rollback":
            runs = [h for h in held if h[1] == "run"]
            if runs:
                run, _ = runs[rng.randrange(len(runs))]
                keep = rng.randint(0, len(run)) * pool.page_size
                pool.rollback_to(run, keep)
                if not run:
                    held.remove((run, "run"))
        elif op == "admit" or (op == "resume" and parked):
            # the InflightDecoder admission shape: a prefix reference
            # (store hit retains, miss allocs + puts) plus a private
            # run. "resume" is the same shape driven by a parked
            # request's key — a preempted request re-enters through
            # ordinary admission, holding nothing in between.
            key = (parked.pop(rng.randrange(len(parked)))
                   if op == "resume"
                   else (f"op{rng.randint(0, 2)}", f"p{rng.randint(0, 3)}"))
            entry = pool.lookup_prefix(key)
            if entry is None:
                ids = pool.alloc(2)
                entry = pool.put_prefix(key, ids, 2 * pool.page_size,
                                        np.zeros((1, 2)))
            else:
                pool.retain(entry.page_ids)
            run = pool.alloc(1)
            slots.append((key, list(entry.page_ids), run))
        elif op == "cancel" and slots:
            # the _release_slot / cancel path: prefix ref and private
            # run return together, mid-decode
            _, ids, run = slots.pop(rng.randrange(len(slots)))
            pool.release(ids)
            pool.release(run)
        elif op == "park" and slots:
            # the _park_slot preemption path: the private run rolls
            # back to the prefix (token-exact resume replays from
            # there) and the prefix reference drops; the parked
            # request holds zero pages while it waits
            key, ids, run = slots.pop(rng.randrange(len(slots)))
            pool.rollback_to(run, 0)
            pool.release(ids)
            parked.append(key)
        _invariant(pool)
    # teardown: every request finishes, every operator leaves
    # (parked requests hold no pages — nothing to return for them)
    for _, ids, run in slots:
        pool.release(ids)
        pool.release(run)
    for ids, _ in held:
        pool.release(ids)
    for op_id in ("op0", "op1", "op2"):
        pool.release_operator(op_id)
    _invariant(pool)
    assert pool.pages_in_use == 0, "pages leaked"


# ---- pages_for sanity ----


@pytest.mark.parametrize("tokens,page,expect",
                         [(0, 4, 0), (1, 4, 1), (4, 4, 1), (5, 4, 2),
                          (16, 16, 1), (17, 16, 2)])
def test_pages_for(tokens, page, expect):
    assert pages_for(tokens, page) == expect


# ---- device-memory residency telemetry (sharded serving) ----


def test_stats_report_pool_residency_per_shard():
    """``stats()`` reports the pool's device residency — total bytes
    (page_bytes x num_pages) and the per-model-shard share — so
    ``kv_pages_peak`` sizing works per device under sharded serving."""
    pool = _pool(shards=4)
    st = pool.stats()
    assert st["kv_pool_bytes"] == pool.page_bytes * pool.num_pages
    assert st["kv_pool_bytes"] > 0
    assert st["kv_pool_bytes_per_shard"] == st["kv_pool_bytes"] // 4
    assert st["kv_shards"] == 4
    # an unsharded pool degenerates to one shard holding everything
    assert _pool().stats()["kv_pool_bytes_per_shard"] \
        == _pool().stats()["kv_pool_bytes"]


def test_placement_applied_on_ensure_and_growth():
    """``placement`` re-places the pool's device buffers on creation
    and on every growth, so the buffers stay mesh-resident as the pool
    doubles (the sharded context passes ``place_pool`` here)."""
    calls = []

    def placement(kv):
        calls.append(sum(l.shape[1] for l in
                         __import__("jax").tree.leaves(kv)) // 2)
        return kv

    pool = _pool(placement=placement)
    assert calls == [pool.num_pages]          # placed at creation
    before = pool.num_pages
    pool.ensure(before + 8)                   # force growth
    assert pool.num_pages > before
    assert calls[-1] == pool.num_pages        # re-placed after growth
    _invariant(pool)
