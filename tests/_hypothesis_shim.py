"""Minimal stand-in for ``hypothesis`` covering exactly the API surface the
suite uses (given / settings / floats / integers / sampled_from / lists /
builds). Imported only when hypothesis isn't installed, so minimal
environments still collect and run the property tests — as deterministic
seeded random sampling rather than guided search + shrinking.
"""
from __future__ import annotations

import functools
import random
from typing import Any, Callable, Optional

_SHIM_MAX_EXAMPLES = 25    # cap: sampling without shrinking gains little more


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self.draw = draw


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
          unique_by: Optional[Callable] = None) -> _Strategy:
    def draw(r: random.Random):
        n = r.randint(min_size, max_size)
        out = []
        for _ in range(max(1, n) * 20):
            if len(out) >= n:
                break
            cand = elements.draw(r)
            if unique_by is not None and any(
                    unique_by(cand) == unique_by(o) for o in out):
                continue
            out.append(cand)
        return out if len(out) >= min_size else out + [elements.draw(r)]
    return _Strategy(draw)


def builds(target: Callable, **kwargs: _Strategy) -> _Strategy:
    return _Strategy(
        lambda r: target(**{k: s.draw(r) for k, s in kwargs.items()}))


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        n = min(getattr(fn, "_shim_max_examples", 20), _SHIM_MAX_EXAMPLES)

        @functools.wraps(fn)
        def wrapper():
            rng = random.Random(fn.__qualname__)
            for _ in range(n):
                fn(**{k: s.draw(rng) for k, s in strategies.items()})
        # pytest follows __wrapped__ to the original signature and would
        # treat the strategy parameters as fixtures
        del wrapper.__wrapped__
        return wrapper
    return deco


class strategies:          # noqa: N801 — mirrors `from hypothesis import strategies as st`
    floats = staticmethod(floats)
    integers = staticmethod(integers)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)
    builds = staticmethod(builds)
